//! The typed deployment specification and its fluent builder.
//!
//! A [`DeploymentSpec`] is the single declarative description of a
//! FlexSpIM deployment: network topology (arbitrary conv/FC stacks with
//! per-layer operand [`Resolution`]), substrate (macro budget, mapping
//! policy, vdd envelope), execution backend, and serve-tier settings.
//! Every section validates with rich errors — a bad spec never panics,
//! it explains itself. Specs come from the [`DeploymentBuilder`], from
//! TOML (see [`super::toml`]), or from the shipped [`super::presets`],
//! and all three produce *identical* values (pinned by
//! `rust/tests/integration_deploy.rs`).

use std::path::PathBuf;

use anyhow::{anyhow, bail, ensure};

use crate::dataflow::Policy;
use crate::snn::{LayerKind, LayerSpec, Network, Resolution};
use crate::Result;

// -------------------------------------------------------------- utilities

/// Parse a policy from its CLI/TOML key (`ws-only`, `os-only`, `hs-min`,
/// `hs-max`, `hs-opt`).
pub fn parse_policy(s: &str) -> Result<Policy> {
    Ok(match s {
        "ws-only" => Policy::WsOnly,
        "os-only" => Policy::OsOnly,
        "hs-min" => Policy::HsMin,
        "hs-max" => Policy::HsMax,
        "hs-opt" => Policy::HsOpt,
        other => bail!("unknown policy '{other}' (ws-only|os-only|hs-min|hs-max|hs-opt)"),
    })
}

/// The CLI/TOML key of a policy (inverse of [`parse_policy`]).
pub fn policy_key(policy: Policy) -> &'static str {
    match policy {
        Policy::WsOnly => "ws-only",
        Policy::OsOnly => "os-only",
        Policy::HsMin => "hs-min",
        Policy::HsMax => "hs-max",
        Policy::HsOpt => "hs-opt",
    }
}

fn check_bits(layer: &str, what: &str, bits: u32) -> Result<()> {
    ensure!(
        (1..=64).contains(&bits),
        "layer {layer}: {what} width {bits} outside the supported 1..=64 bits"
    );
    Ok(())
}

// ------------------------------------------------------------- layer defs

/// One layer of a [`NetworkSpec`] in raw, unvalidated form.
///
/// Unlike [`LayerSpec`] (whose constructors assert), a `LayerDef` can hold
/// any values and is checked by [`NetworkSpec::validate`] with rich
/// errors. Thresholds follow the resolution-derived default
/// ([`crate::snn::layer::default_threshold`]).
#[derive(Debug, Clone, PartialEq)]
pub enum LayerDef {
    /// 2-D convolution over a `in_ch × in_h × in_w` spike tensor.
    Conv {
        /// Layer name for reports.
        name: String,
        /// Input channels.
        in_ch: usize,
        /// Output channels.
        out_ch: usize,
        /// Square kernel size.
        k: usize,
        /// Stride (same both dims).
        stride: usize,
        /// Symmetric zero padding.
        pad: usize,
        /// Input height.
        in_h: usize,
        /// Input width.
        in_w: usize,
        /// Weight bit-width.
        w_bits: u32,
        /// Membrane-potential bit-width.
        p_bits: u32,
    },
    /// Fully-connected layer.
    Fc {
        /// Layer name for reports.
        name: String,
        /// Input neurons.
        in_dim: usize,
        /// Output neurons.
        out_dim: usize,
        /// Weight bit-width.
        w_bits: u32,
        /// Membrane-potential bit-width.
        p_bits: u32,
    },
}

impl LayerDef {
    /// The layer's name.
    pub fn name(&self) -> &str {
        match self {
            LayerDef::Conv { name, .. } | LayerDef::Fc { name, .. } => name,
        }
    }

    /// Capture an already-validated [`LayerSpec`] (presets, `--full`).
    pub fn from_spec(spec: &LayerSpec) -> LayerDef {
        match spec.kind {
            LayerKind::Conv { in_ch, out_ch, k, stride, pad, in_h, in_w } => LayerDef::Conv {
                name: spec.name.clone(),
                in_ch,
                out_ch,
                k,
                stride,
                pad,
                in_h,
                in_w,
                w_bits: spec.res.w_bits,
                p_bits: spec.res.p_bits,
            },
            LayerKind::Fc { in_dim, out_dim } => LayerDef::Fc {
                name: spec.name.clone(),
                in_dim,
                out_dim,
                w_bits: spec.res.w_bits,
                p_bits: spec.res.p_bits,
            },
        }
    }

    /// Validate this definition and lower it to a [`LayerSpec`].
    pub fn build(&self) -> Result<LayerSpec> {
        match self {
            LayerDef::Conv {
                name,
                in_ch,
                out_ch,
                k,
                stride,
                pad,
                in_h,
                in_w,
                w_bits,
                p_bits,
            } => {
                ensure!(!name.is_empty(), "conv layer with an empty name");
                check_bits(name, "weight", *w_bits)?;
                check_bits(name, "membrane", *p_bits)?;
                ensure!(*in_ch > 0 && *out_ch > 0, "layer {name}: channel counts must be > 0");
                ensure!(*k > 0, "layer {name}: kernel size must be > 0");
                ensure!(*stride > 0, "layer {name}: stride must be > 0");
                ensure!(
                    *in_h >= *k && *in_w >= *k,
                    "layer {name}: input {in_h}x{in_w} smaller than the {k}x{k} kernel"
                );
                Ok(LayerSpec::conv(
                    name,
                    *in_ch,
                    *out_ch,
                    *k,
                    *stride,
                    *pad,
                    *in_h,
                    *in_w,
                    Resolution::new(*w_bits, *p_bits),
                ))
            }
            LayerDef::Fc { name, in_dim, out_dim, w_bits, p_bits } => {
                ensure!(!name.is_empty(), "fc layer with an empty name");
                check_bits(name, "weight", *w_bits)?;
                check_bits(name, "membrane", *p_bits)?;
                ensure!(
                    *in_dim > 0 && *out_dim > 0,
                    "layer {name}: fc dimensions must be > 0"
                );
                Ok(LayerSpec::fc(name, *in_dim, *out_dim, Resolution::new(*w_bits, *p_bits)))
            }
        }
    }
}

// ----------------------------------------------------------- network spec

/// Network topology section of a [`DeploymentSpec`].
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkSpec {
    /// Model name for reports.
    pub name: String,
    /// Timesteps per inference.
    pub timesteps: usize,
    /// Layers, input to output.
    pub layers: Vec<LayerDef>,
}

impl NetworkSpec {
    /// An empty topology (layers added by the builder / TOML loader).
    pub fn new(name: &str, timesteps: usize) -> NetworkSpec {
        NetworkSpec { name: name.to_string(), timesteps, layers: Vec::new() }
    }

    /// Capture an already-validated [`Network`].
    pub fn from_network(net: &Network) -> NetworkSpec {
        NetworkSpec {
            name: net.name.clone(),
            timesteps: net.timesteps,
            layers: net.layers.iter().map(LayerDef::from_spec).collect(),
        }
    }

    /// Validate the topology: per-layer geometry/resolution plus the
    /// inter-layer shape chain, with errors that name the offending
    /// layers and sizes.
    pub fn validate(&self) -> Result<()> {
        self.build_layers().map(|_| ())
    }

    fn build_layers(&self) -> Result<Vec<LayerSpec>> {
        ensure!(!self.layers.is_empty(), "network '{}' has no layers", self.name);
        ensure!(
            (1..=1024).contains(&self.timesteps),
            "network '{}': timesteps {} outside 1..=1024",
            self.name,
            self.timesteps
        );
        let specs: Vec<LayerSpec> =
            self.layers.iter().map(LayerDef::build).collect::<Result<_>>()?;
        for w in specs.windows(2) {
            let (c, h, wd) = w[0].out_shape();
            let expect = c * h * wd;
            let (ic, ih, iw) = w[1].in_shape();
            let got = ic * ih * iw;
            ensure!(
                expect == got,
                "shape chain broken between {} and {}: {} emits {}x{}x{} = {} neurons \
                 but {} expects {}x{}x{} = {}",
                w[0].name,
                w[1].name,
                w[0].name,
                c,
                h,
                wd,
                expect,
                w[1].name,
                ic,
                ih,
                iw,
                got
            );
        }
        // The runtime's rate-coded head (engine, serve sessions, traffic
        // labels) is 10-class DVS gesture throughout; a wider classifier
        // would index past the rate vector at runtime, so reject it here.
        let last = specs.last().expect("checked non-empty");
        let (c, h, wd) = last.out_shape();
        ensure!(
            c * h * wd == 10,
            "network '{}': classifier layer {} emits {} outputs, but the rate-coded \
             head is 10-class (DVS gesture) — end the stack in 10 outputs",
            self.name,
            last.name,
            c * h * wd
        );
        Ok(specs)
    }

    /// Lower to a validated [`Network`].
    pub fn build(&self) -> Result<Network> {
        let layers = self.build_layers()?;
        Ok(Network::new(&self.name, layers, self.timesteps))
    }

    /// Input shape `(channels, height, width)` of the first layer.
    pub fn input_shape(&self) -> Result<(usize, usize, usize)> {
        let first = self
            .layers
            .first()
            .ok_or_else(|| anyhow!("network '{}' has no layers", self.name))?;
        Ok(match *first {
            LayerDef::Conv { in_ch, in_h, in_w, .. } => (in_ch, in_h, in_w),
            LayerDef::Fc { in_dim, .. } => (in_dim, 1, 1),
        })
    }
}

// --------------------------------------------------------- substrate spec

/// Substrate section: the modeled hardware budget and operating point.
#[derive(Debug, Clone, PartialEq)]
pub struct SubstrateSpec {
    /// Number of CIM macros.
    pub macros: usize,
    /// Dataflow mapping policy.
    pub policy: Policy,
    /// Supply voltage (the silicon envelope is 0.9–1.1 V).
    pub vdd: f64,
}

impl Default for SubstrateSpec {
    fn default() -> Self {
        SubstrateSpec { macros: 16, policy: Policy::HsOpt, vdd: 1.1 }
    }
}

impl SubstrateSpec {
    /// Sanity limits (same envelope the energy model enforces).
    pub fn validate(&self) -> Result<()> {
        ensure!(
            (1..=4096).contains(&self.macros),
            "substrate: {} macros outside 1..=4096",
            self.macros
        );
        ensure!(
            (0.9..=1.1).contains(&self.vdd),
            "substrate: vdd {} V outside the 0.9-1.1 V silicon envelope",
            self.vdd
        );
        Ok(())
    }
}

// ----------------------------------------------------------- backend spec

/// Execution backend selection.
#[derive(Debug, Clone, PartialEq)]
pub enum BackendSpec {
    /// Pure-Rust event-driven sparse backend, deterministic from `seed`;
    /// runs everywhere, no artifacts.
    Native {
        /// Weight-stream seed.
        seed: u64,
    },
    /// Dense golden-reference backend over the same weight streams (the
    /// oracle path — slow, for validation runs only).
    NativeDense {
        /// Weight-stream seed.
        seed: u64,
    },
    /// PJRT runtime executing the AOT HLO artifacts (`make artifacts`).
    Pjrt {
        /// Artifacts directory; `None` resolves via
        /// [`crate::runtime::artifacts_dir`].
        artifacts: Option<PathBuf>,
    },
}

impl Default for BackendSpec {
    fn default() -> Self {
        BackendSpec::Native { seed: 42 }
    }
}

impl BackendSpec {
    /// The TOML/CLI key of this backend kind.
    pub fn kind(&self) -> &'static str {
        match self {
            BackendSpec::Native { .. } => "native",
            BackendSpec::NativeDense { .. } => "native-dense",
            BackendSpec::Pjrt { .. } => "pjrt",
        }
    }

    /// The weight-stream seed, for the seeded (native) backends.
    pub fn seed(&self) -> Option<u64> {
        match self {
            BackendSpec::Native { seed } | BackendSpec::NativeDense { seed } => Some(*seed),
            BackendSpec::Pjrt { .. } => None,
        }
    }
}

// -------------------------------------------------------------- serve spec

/// SLO autoscaler section of a [`ServeSpec`]: grows/shrinks the worker
/// pool from queue depth and rolling p99 (see
/// [`crate::serve::AutoscaleConfig`] for the control semantics).
#[derive(Debug, Clone, PartialEq)]
pub struct AutoscaleSpec {
    /// Master switch; when off the pool stays at `workers`.
    pub enabled: bool,
    /// Pool floor the autoscaler never shrinks below.
    pub min_workers: usize,
    /// Pool ceiling the autoscaler never grows past (bounds the spawned
    /// threads).
    pub max_workers: usize,
    /// Latency objective: rolling p99 window latency above this grows the
    /// pool (milliseconds).
    pub slo_p99_ms: f64,
    /// Control-loop tick interval (milliseconds).
    pub interval_ms: u64,
    /// Queued windows per active worker considered overloaded even when
    /// the latency SLO still holds.
    pub queue_high: usize,
    /// Consecutive calm ticks required before one shrink step
    /// (hysteresis: a single quiet tick must not flap the pool).
    pub hysteresis_ticks: u32,
}

impl Default for AutoscaleSpec {
    fn default() -> Self {
        AutoscaleSpec {
            enabled: false,
            min_workers: 1,
            max_workers: 16,
            slo_p99_ms: 20.0,
            interval_ms: 10,
            queue_high: 8,
            hysteresis_ticks: 5,
        }
    }
}

/// Serve-tier section: worker pool, queues, residency, admission mode,
/// early exit, session clock overrides, and the SLO autoscaler (see
/// [`crate::serve::ServiceConfig`] for semantics).
#[derive(Debug, Clone, PartialEq)]
pub struct ServeSpec {
    /// Worker threads (each constructs its own backend). With the
    /// autoscaler enabled this is the *starting* pool size.
    pub workers: usize,
    /// Global bound on admitted-but-unexecuted windows.
    pub queue_capacity: usize,
    /// Per-session bound on queued windows.
    pub per_session_capacity: usize,
    /// Vmem residency budget in kB; `0` derives it from the modeled chip
    /// capacity (CIM array + global buffer).
    pub resident_budget_kb: u64,
    /// Dispatch windows in global admission order (bit-reproducible
    /// residency/energy reports at any worker count). The guarantee is
    /// scoped to shed-free runs: shedding decisions depend on worker
    /// drain timing, so an overloaded queue reintroduces pool-size
    /// dependence.
    pub deterministic_admission: bool,
    /// Early-exit confidence margin (`0` disables).
    pub early_exit_margin: f64,
    /// Executed windows required before early exit may trigger.
    pub early_exit_min_windows: u64,
    /// Session clock override: microseconds per SNN timestep. `None`
    /// derives it from the network's timestep count (the historical
    /// behaviour, pinned in `deploy::handle`).
    pub step_us: Option<u64>,
    /// Session clock override: timesteps per emitted micro-window.
    /// `None` derives it from the network (`timesteps.min(4)`).
    pub frames_per_window: Option<usize>,
    /// SLO-driven worker-pool autoscaler.
    pub autoscale: AutoscaleSpec,
}

impl Default for ServeSpec {
    fn default() -> Self {
        ServeSpec {
            workers: 4,
            queue_capacity: 4096,
            per_session_capacity: 256,
            resident_budget_kb: 0,
            deterministic_admission: false,
            early_exit_margin: 0.0,
            early_exit_min_windows: 2,
            step_us: None,
            frames_per_window: None,
            autoscale: AutoscaleSpec::default(),
        }
    }
}

impl ServeSpec {
    /// Sanity limits.
    pub fn validate(&self) -> Result<()> {
        ensure!(
            (1..=256).contains(&self.workers),
            "serve: {} workers outside 1..=256",
            self.workers
        );
        ensure!(
            self.early_exit_margin >= 0.0,
            "serve: early-exit margin {} must be >= 0",
            self.early_exit_margin
        );
        if let Some(step) = self.step_us {
            ensure!(
                (1..=10_000_000).contains(&step),
                "serve: step_us {step} outside 1..=10000000 (10 s/timestep cap)"
            );
        }
        if let Some(frames) = self.frames_per_window {
            ensure!(
                (1..=1024).contains(&frames),
                "serve: frames_per_window {frames} outside 1..=1024"
            );
        }
        let a = &self.autoscale;
        if a.enabled {
            ensure!(a.min_workers >= 1, "serve: autoscale min_workers must be >= 1");
            ensure!(
                a.min_workers <= self.workers && self.workers <= a.max_workers,
                "serve: workers {} outside the autoscale range {}..={}",
                self.workers,
                a.min_workers,
                a.max_workers
            );
            ensure!(
                a.max_workers <= 256,
                "serve: autoscale max_workers {} outside 1..=256",
                a.max_workers
            );
            ensure!(
                a.slo_p99_ms > 0.0,
                "serve: autoscale slo_p99_ms {} must be > 0",
                a.slo_p99_ms
            );
            ensure!(a.interval_ms >= 1, "serve: autoscale interval_ms must be >= 1");
            ensure!(a.queue_high >= 1, "serve: autoscale queue_high must be >= 1");
            ensure!(
                a.hysteresis_ticks >= 1,
                "serve: autoscale hysteresis_ticks must be >= 1"
            );
        }
        Ok(())
    }
}

// ------------------------------------------------------------- telemetry

/// Telemetry section: metrics/flight-recorder switches and the span
/// tracer's sampling knob (see [`crate::telemetry`] for semantics).
/// Everything defaults to off, so a plain spec records nothing and the
/// instrumentation sites cost one relaxed atomic load.
#[derive(Debug, Clone, PartialEq)]
pub struct TelemetrySpec {
    /// Record service metrics and flight-recorder events; deploying an
    /// enabled spec also flips the process-global
    /// [`crate::telemetry::set_enabled`] switch (engine hot-path
    /// counters).
    pub enabled: bool,
    /// Record scoped spans for Chrome-trace export (implies nothing
    /// about `enabled`; the two can be toggled independently).
    pub trace: bool,
    /// Span sampling period: record every n-th span site hit (1 =
    /// every span). Ignored while `trace` is off.
    pub trace_sample: u32,
    /// Flight-recorder ring capacity (last N events retained).
    pub flight_capacity: usize,
}

impl Default for TelemetrySpec {
    fn default() -> Self {
        TelemetrySpec {
            enabled: false,
            trace: false,
            trace_sample: 64,
            flight_capacity: 256,
        }
    }
}

impl TelemetrySpec {
    /// Sanity limits.
    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.trace_sample >= 1,
            "telemetry: trace_sample must be >= 1 (1 records every span)"
        );
        ensure!(
            (1..=1_048_576).contains(&self.flight_capacity),
            "telemetry: flight_capacity {} outside 1..=1048576",
            self.flight_capacity
        );
        Ok(())
    }
}

// -------------------------------------------------------- precision spec

/// Precision-controller section: per-session serve-time resolution
/// adaptation (see [`crate::serve::PrecisionConfig`] for the control
/// semantics). Defaults to off, so a plain spec serves every window at
/// the deployed (tier-0) resolution.
#[derive(Debug, Clone, PartialEq)]
pub struct PrecisionSpec {
    /// Master switch; when off every session stays at tier 0.
    pub enabled: bool,
    /// Deepest tier: every layer may lose up to this many bits
    /// (1..=7; the fig6 floor of 2 weight / 4 membrane bits still
    /// applies per layer).
    pub max_delta: u32,
    /// Rolling-p99 window latency above which a session drops one tier
    /// (milliseconds).
    pub drop_p99_ms: f64,
    /// Queued windows per active worker considered overloaded.
    pub queue_high: usize,
    /// Smoothed classification margin below which a session is raised
    /// one tier back toward full precision.
    pub raise_margin: f64,
    /// Executed windows required before margin-driven raises may
    /// trigger.
    pub min_windows: u64,
}

impl Default for PrecisionSpec {
    fn default() -> Self {
        PrecisionSpec {
            enabled: false,
            max_delta: 3,
            drop_p99_ms: 20.0,
            queue_high: 8,
            raise_margin: 0.5,
            min_windows: 2,
        }
    }
}

impl PrecisionSpec {
    /// Sanity limits.
    pub fn validate(&self) -> Result<()> {
        ensure!(
            (1..=crate::serve::precision::MAX_DELTA_LIMIT).contains(&self.max_delta),
            "precision: max_delta {} outside 1..={}",
            self.max_delta,
            crate::serve::precision::MAX_DELTA_LIMIT
        );
        ensure!(
            self.drop_p99_ms > 0.0,
            "precision: drop_p99_ms {} must be > 0",
            self.drop_p99_ms
        );
        ensure!(self.queue_high >= 1, "precision: queue_high must be >= 1");
        ensure!(
            self.raise_margin >= 0.0,
            "precision: raise_margin {} must be >= 0",
            self.raise_margin
        );
        Ok(())
    }
}

// ------------------------------------------------------------ fleet spec

/// Weight-placement policy of a fleet (see [`crate::fleet`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// Every node holds the full weight set; a session runs anywhere and
    /// joins cost one broadcast weight push.
    Replicated,
    /// Layers are partitioned round-robin across nodes; joins cost one
    /// unicast per shard and every executed window pays modeled
    /// inter-shard boundary-spike traffic.
    LayerSharded,
}

impl Placement {
    /// The TOML/CLI key of this placement (`replicated` | `layer-sharded`).
    pub fn key(&self) -> &'static str {
        match self {
            Placement::Replicated => "replicated",
            Placement::LayerSharded => "layer-sharded",
        }
    }

    /// Parse from the TOML/CLI key (inverse of [`Placement::key`]).
    pub fn parse(s: &str) -> Result<Placement> {
        Ok(match s {
            "replicated" => Placement::Replicated,
            "layer-sharded" => Placement::LayerSharded,
            other => bail!("unknown placement '{other}' (replicated|layer-sharded)"),
        })
    }
}

/// Fleet section: scale-out across N accelerator nodes (see
/// [`crate::fleet`] for routing/migration semantics). Defaults to a
/// single node, so a plain spec deploys exactly as before.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetSpec {
    /// Replica nodes at boot.
    pub nodes: usize,
    /// Weight-placement policy.
    pub placement: Placement,
    /// Sticky-session capacity per node; the router spills past a full
    /// node to the next ring successor (`0` = unbounded).
    pub capacity_sessions: usize,
    /// Virtual nodes per physical node on the consistent-hash ring
    /// (more vnodes = smoother key spread, larger ring).
    pub vnodes: usize,
    /// Inter-node link energy per transferred bit (pJ/bit). Default 30:
    /// a chip-to-chip serial link priced above the 20 pJ/bit DRAM lane.
    pub link_pj_per_bit: f64,
    /// Autoscale ceiling: the fleet may grow itself up to this many
    /// nodes (`0` disables autoscale joins; otherwise must be >= `nodes`).
    pub max_nodes: usize,
    /// Mean live sessions per node above which an autoscale join fires
    /// (ignored while `max_nodes` is 0).
    pub scale_high_sessions: usize,
}

impl Default for FleetSpec {
    fn default() -> Self {
        FleetSpec {
            nodes: 1,
            placement: Placement::Replicated,
            capacity_sessions: 0,
            vnodes: 16,
            link_pj_per_bit: 30.0,
            max_nodes: 0,
            scale_high_sessions: 8,
        }
    }
}

impl FleetSpec {
    /// Sanity limits.
    pub fn validate(&self) -> Result<()> {
        ensure!(
            (1..=64).contains(&self.nodes),
            "fleet: {} nodes outside 1..=64",
            self.nodes
        );
        ensure!(
            (1..=1024).contains(&self.vnodes),
            "fleet: vnodes {} outside 1..=1024",
            self.vnodes
        );
        ensure!(
            self.link_pj_per_bit >= 0.0,
            "fleet: link_pj_per_bit {} must be >= 0",
            self.link_pj_per_bit
        );
        if self.max_nodes > 0 {
            ensure!(
                self.max_nodes >= self.nodes,
                "fleet: max_nodes {} below the boot size {}",
                self.max_nodes,
                self.nodes
            );
            ensure!(
                self.max_nodes <= 64,
                "fleet: max_nodes {} outside 1..=64",
                self.max_nodes
            );
            ensure!(
                self.scale_high_sessions >= 1,
                "fleet: scale_high_sessions must be >= 1 when autoscale is on"
            );
        }
        Ok(())
    }
}

// -------------------------------------------------------- deployment spec

/// The one typed description of a FlexSpIM deployment: topology,
/// substrate, backend, and serve settings. Construct with
/// [`DeploymentSpec::builder`], load from TOML with
/// [`DeploymentSpec::from_toml_str`] / [`DeploymentSpec::load`], then
/// materialize any tier via [`DeploymentSpec::deploy`].
#[derive(Debug, Clone, PartialEq)]
pub struct DeploymentSpec {
    /// Network topology.
    pub network: NetworkSpec,
    /// Hardware budget and operating point.
    pub substrate: SubstrateSpec,
    /// Execution backend.
    pub backend: BackendSpec,
    /// Serve-tier settings.
    pub serve: ServeSpec,
    /// Telemetry settings (metrics, tracing, flight recorder).
    pub telemetry: TelemetrySpec,
    /// Serve-time precision-controller settings.
    pub precision: PrecisionSpec,
    /// Fleet scale-out settings.
    pub fleet: FleetSpec,
}

impl DeploymentSpec {
    /// Start a fluent builder for a network named `name`.
    pub fn builder(name: &str) -> DeploymentBuilder {
        DeploymentBuilder {
            network: NetworkSpec::new(name, 16),
            substrate: SubstrateSpec::default(),
            backend: BackendSpec::default(),
            serve: ServeSpec::default(),
            telemetry: TelemetrySpec::default(),
            precision: PrecisionSpec::default(),
            fleet: FleetSpec::default(),
        }
    }

    /// Validate every section.
    pub fn validate(&self) -> Result<()> {
        self.network.validate()?;
        self.substrate.validate()?;
        self.serve.validate()?;
        self.telemetry.validate()?;
        self.precision.validate()?;
        self.fleet.validate()?;
        Ok(())
    }
}

// ---------------------------------------------------------------- builder

/// Fluent builder for a [`DeploymentSpec`].
///
/// ```no_run
/// use flexspim::dataflow::Policy;
/// use flexspim::deploy::DeploymentSpec;
/// use flexspim::snn::Resolution;
///
/// let spec = DeploymentSpec::builder("demo")
///     .timesteps(16)
///     .conv("C1", 2, 8, 3, 4, 1, 48, 48, Resolution::new(4, 9))
///     .fc("F1", 8 * 12 * 12, 10, Resolution::new(5, 10))
///     .macros(4)
///     .policy(Policy::HsOpt)
///     .native_backend(42)
///     .workers(2)
///     .build()
///     .unwrap();
/// let service = spec.deploy().unwrap().service().unwrap();
/// ```
#[derive(Debug, Clone)]
pub struct DeploymentBuilder {
    network: NetworkSpec,
    substrate: SubstrateSpec,
    backend: BackendSpec,
    serve: ServeSpec,
    telemetry: TelemetrySpec,
    precision: PrecisionSpec,
    fleet: FleetSpec,
}

impl DeploymentBuilder {
    /// Timesteps per inference.
    pub fn timesteps(mut self, timesteps: usize) -> Self {
        self.network.timesteps = timesteps;
        self
    }

    /// Append a conv layer (same argument order as
    /// [`LayerSpec::conv`]).
    #[allow(clippy::too_many_arguments)]
    pub fn conv(
        mut self,
        name: &str,
        in_ch: usize,
        out_ch: usize,
        k: usize,
        stride: usize,
        pad: usize,
        in_h: usize,
        in_w: usize,
        res: Resolution,
    ) -> Self {
        self.network.layers.push(LayerDef::Conv {
            name: name.to_string(),
            in_ch,
            out_ch,
            k,
            stride,
            pad,
            in_h,
            in_w,
            w_bits: res.w_bits,
            p_bits: res.p_bits,
        });
        self
    }

    /// Append a fully-connected layer.
    pub fn fc(mut self, name: &str, in_dim: usize, out_dim: usize, res: Resolution) -> Self {
        self.network.layers.push(LayerDef::Fc {
            name: name.to_string(),
            in_dim,
            out_dim,
            w_bits: res.w_bits,
            p_bits: res.p_bits,
        });
        self
    }

    /// Append a raw layer definition.
    pub fn layer(mut self, layer: LayerDef) -> Self {
        self.network.layers.push(layer);
        self
    }

    /// Replace the whole topology (name, layers, timesteps) with an
    /// existing [`Network`].
    pub fn network(mut self, net: &Network) -> Self {
        self.network = NetworkSpec::from_network(net);
        self
    }

    /// Number of CIM macros.
    pub fn macros(mut self, macros: usize) -> Self {
        self.substrate.macros = macros;
        self
    }

    /// Dataflow mapping policy.
    pub fn policy(mut self, policy: Policy) -> Self {
        self.substrate.policy = policy;
        self
    }

    /// Supply voltage (0.9–1.1 V envelope).
    pub fn vdd(mut self, vdd: f64) -> Self {
        self.substrate.vdd = vdd;
        self
    }

    /// Explicit backend selection.
    pub fn backend(mut self, backend: BackendSpec) -> Self {
        self.backend = backend;
        self
    }

    /// Shortcut: the pure-Rust sparse backend with this seed.
    pub fn native_backend(self, seed: u64) -> Self {
        self.backend(BackendSpec::Native { seed })
    }

    /// Shortcut: the PJRT backend (artifacts auto-located when `None`).
    pub fn pjrt_backend(self, artifacts: Option<PathBuf>) -> Self {
        self.backend(BackendSpec::Pjrt { artifacts })
    }

    /// Serve-tier worker threads.
    pub fn workers(mut self, workers: usize) -> Self {
        self.serve.workers = workers;
        self
    }

    /// Global admitted-window queue bound.
    pub fn queue_capacity(mut self, cap: usize) -> Self {
        self.serve.queue_capacity = cap;
        self
    }

    /// Per-session queued-window bound.
    pub fn per_session_capacity(mut self, cap: usize) -> Self {
        self.serve.per_session_capacity = cap;
        self
    }

    /// Vmem residency budget in kB (`0` = modeled chip capacity).
    pub fn resident_budget_kb(mut self, kb: u64) -> Self {
        self.serve.resident_budget_kb = kb;
        self
    }

    /// Dispatch windows in global admission order.
    pub fn deterministic_admission(mut self, on: bool) -> Self {
        self.serve.deterministic_admission = on;
        self
    }

    /// Early-exit confidence margin (`0` disables) and the minimum
    /// executed windows before it may trigger.
    pub fn early_exit(mut self, margin: f64, min_windows: u64) -> Self {
        self.serve.early_exit_margin = margin;
        self.serve.early_exit_min_windows = min_windows;
        self
    }

    /// Override the serve session clock: microseconds per SNN timestep
    /// and timesteps per emitted micro-window.
    pub fn session_clock(mut self, step_us: u64, frames_per_window: usize) -> Self {
        self.serve.step_us = Some(step_us);
        self.serve.frames_per_window = Some(frames_per_window);
        self
    }

    /// Replace the whole autoscaler section.
    pub fn autoscale(mut self, spec: AutoscaleSpec) -> Self {
        self.serve.autoscale = spec;
        self
    }

    /// Shortcut: enable the autoscaler with a p99 latency objective (ms)
    /// and a pool ceiling, keeping the remaining knobs at their defaults.
    pub fn autoscale_slo(mut self, slo_p99_ms: f64, max_workers: usize) -> Self {
        self.serve.autoscale.enabled = true;
        self.serve.autoscale.slo_p99_ms = slo_p99_ms;
        self.serve.autoscale.max_workers = max_workers;
        self
    }

    /// Replace the whole telemetry section.
    pub fn telemetry(mut self, spec: TelemetrySpec) -> Self {
        self.telemetry = spec;
        self
    }

    /// Shortcut: turn metrics + flight-recorder telemetry on/off,
    /// keeping the remaining knobs at their defaults.
    pub fn telemetry_enabled(mut self, on: bool) -> Self {
        self.telemetry.enabled = on;
        self
    }

    /// Shortcut: enable span tracing at the given sampling period
    /// (1 = record every span).
    pub fn tracing(mut self, sample_every: u32) -> Self {
        self.telemetry.trace = true;
        self.telemetry.trace_sample = sample_every;
        self
    }

    /// Replace the whole precision-controller section.
    pub fn precision(mut self, spec: PrecisionSpec) -> Self {
        self.precision = spec;
        self
    }

    /// Shortcut: enable serve-time precision adaptation with a drop
    /// threshold (rolling p99, ms) and a deepest tier, keeping the
    /// remaining knobs at their defaults.
    pub fn adaptive_precision(mut self, drop_p99_ms: f64, max_delta: u32) -> Self {
        self.precision.enabled = true;
        self.precision.drop_p99_ms = drop_p99_ms;
        self.precision.max_delta = max_delta;
        self
    }

    /// Replace the whole fleet section.
    pub fn fleet(mut self, spec: FleetSpec) -> Self {
        self.fleet = spec;
        self
    }

    /// Shortcut: a fleet of `nodes` replicas, keeping the remaining
    /// fleet knobs at their defaults.
    pub fn fleet_nodes(mut self, nodes: usize) -> Self {
        self.fleet.nodes = nodes;
        self
    }

    /// Shortcut: enable autoscale joins up to `max_nodes` once the mean
    /// live sessions per node crosses `high_sessions`.
    pub fn fleet_autoscale(mut self, high_sessions: usize, max_nodes: usize) -> Self {
        self.fleet.scale_high_sessions = high_sessions;
        self.fleet.max_nodes = max_nodes;
        self
    }

    /// Validate and produce the spec.
    pub fn build(self) -> Result<DeploymentSpec> {
        let spec = DeploymentSpec {
            network: self.network,
            substrate: self.substrate,
            backend: self.backend,
            serve: self.serve,
            telemetry: self.telemetry,
            precision: self.precision,
            fleet: self.fleet,
        };
        spec.validate()?;
        Ok(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::network::scnn_dvs_gesture;

    #[test]
    fn builder_produces_a_valid_spec() {
        let spec = DeploymentSpec::builder("t")
            .timesteps(8)
            .conv("C1", 2, 4, 3, 4, 1, 48, 48, Resolution::new(4, 9))
            .fc("F1", 4 * 12 * 12, 10, Resolution::new(5, 10))
            .macros(2)
            .native_backend(7)
            .workers(2)
            .build()
            .unwrap();
        assert_eq!(spec.network.layers.len(), 2);
        assert_eq!(spec.backend.seed(), Some(7));
        let net = spec.network.build().unwrap();
        assert_eq!(net.timesteps, 8);
        assert_eq!(net.layers[1].out_shape(), (10, 1, 1));
    }

    #[test]
    fn network_spec_round_trips_the_reference_scnn() {
        let net = scnn_dvs_gesture();
        let spec = NetworkSpec::from_network(&net);
        let rebuilt = spec.build().unwrap();
        assert_eq!(rebuilt.layers.len(), net.layers.len());
        assert_eq!(rebuilt.timesteps, net.timesteps);
        assert_eq!(rebuilt.total_weight_bits(), net.total_weight_bits());
        assert_eq!(rebuilt.total_vmem_bits(), net.total_vmem_bits());
        for (a, b) in rebuilt.layers.iter().zip(&net.layers) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.res, b.res);
            assert_eq!(a.threshold, b.threshold);
        }
    }

    #[test]
    fn shape_chain_mismatch_is_a_rich_error() {
        let err = DeploymentSpec::builder("bad")
            .fc("a", 10, 20, Resolution::new(4, 8))
            .fc("b", 21, 5, Resolution::new(4, 8))
            .build()
            .unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("shape chain"), "got: {msg}");
        assert!(msg.contains('a') && msg.contains('b'));
        assert!(msg.contains("20") && msg.contains("21"));
    }

    #[test]
    fn invalid_sections_rejected() {
        let base = || {
            DeploymentSpec::builder("t").fc("f", 4, 10, Resolution::new(4, 8))
        };
        assert!(base().build().is_ok());
        assert!(base().workers(0).build().is_err(), "zero workers");
        assert!(base().macros(0).build().is_err(), "zero macros");
        assert!(base().vdd(1.5).build().is_err(), "vdd envelope");
        assert!(base().timesteps(0).build().is_err(), "zero timesteps");
        assert!(base().early_exit(-0.5, 1).build().is_err(), "negative margin");
        assert!(base().session_clock(0, 4).build().is_err(), "zero step_us");
        assert!(base().session_clock(6_250, 0).build().is_err(), "zero frames");
        assert!(
            base().workers(8).autoscale_slo(10.0, 4).build().is_err(),
            "workers above autoscale ceiling"
        );
        assert!(base().autoscale_slo(0.0, 4).build().is_err(), "zero SLO");
        let bad = AutoscaleSpec {
            enabled: true,
            hysteresis_ticks: 0,
            ..AutoscaleSpec::default()
        };
        assert!(base().workers(1).autoscale(bad).build().is_err(), "zero hysteresis");
        let bad_tl = TelemetrySpec { trace_sample: 0, ..TelemetrySpec::default() };
        assert!(base().telemetry(bad_tl).build().is_err(), "zero trace_sample");
        let bad_tl = TelemetrySpec { flight_capacity: 0, ..TelemetrySpec::default() };
        assert!(base().telemetry(bad_tl).build().is_err(), "zero flight_capacity");
        let bad_pr = PrecisionSpec { max_delta: 0, ..PrecisionSpec::default() };
        assert!(base().precision(bad_pr).build().is_err(), "zero max_delta");
        let bad_pr = PrecisionSpec { max_delta: 8, ..PrecisionSpec::default() };
        assert!(base().precision(bad_pr).build().is_err(), "max_delta past tier table");
        let bad_pr = PrecisionSpec { drop_p99_ms: 0.0, ..PrecisionSpec::default() };
        assert!(base().precision(bad_pr).build().is_err(), "zero drop_p99_ms");
        let bad_pr = PrecisionSpec { raise_margin: -0.5, ..PrecisionSpec::default() };
        assert!(base().precision(bad_pr).build().is_err(), "negative raise_margin");
        assert!(base().fleet_nodes(0).build().is_err(), "zero fleet nodes");
        assert!(base().fleet_nodes(65).build().is_err(), "fleet nodes past 64");
        let bad_fl = FleetSpec { vnodes: 0, ..FleetSpec::default() };
        assert!(base().fleet(bad_fl).build().is_err(), "zero vnodes");
        let bad_fl = FleetSpec { link_pj_per_bit: -1.0, ..FleetSpec::default() };
        assert!(base().fleet(bad_fl).build().is_err(), "negative link energy");
        assert!(
            base().fleet_nodes(4).fleet_autoscale(8, 2).build().is_err(),
            "autoscale ceiling below boot size"
        );
        assert!(
            base().fleet_autoscale(0, 4).build().is_err(),
            "zero scale_high_sessions with autoscale on"
        );
        let mut bad_bits = base().build().unwrap();
        bad_bits.network.layers[0] = LayerDef::Fc {
            name: "f".into(),
            in_dim: 4,
            out_dim: 10,
            w_bits: 0,
            p_bits: 8,
        };
        assert!(bad_bits.validate().is_err(), "zero-width weights");
    }

    #[test]
    fn non_ten_class_head_rejected() {
        let err = DeploymentSpec::builder("wide")
            .fc("f", 4, 16, Resolution::new(4, 8))
            .build()
            .unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("10-class"), "got: {msg}");
        assert!(msg.contains("16"), "got: {msg}");
    }

    #[test]
    fn policy_keys_round_trip() {
        for p in Policy::ALL {
            assert_eq!(parse_policy(policy_key(p)).unwrap(), p);
        }
        assert!(parse_policy("nope").is_err());
    }

    #[test]
    fn autoscale_and_clock_builder_paths() {
        let spec = DeploymentSpec::builder("t")
            .fc("f", 4, 10, Resolution::new(4, 8))
            .workers(2)
            .session_clock(12_500, 2)
            .autoscale_slo(5.0, 8)
            .build()
            .unwrap();
        assert_eq!(spec.serve.step_us, Some(12_500));
        assert_eq!(spec.serve.frames_per_window, Some(2));
        assert!(spec.serve.autoscale.enabled);
        assert_eq!(spec.serve.autoscale.max_workers, 8);
        assert!((spec.serve.autoscale.slo_p99_ms - 5.0).abs() < 1e-12);
        // Disabled autoscaler skips range coupling: workers above the
        // (unused) ceiling stays valid.
        let off = DeploymentSpec::builder("t")
            .fc("f", 4, 10, Resolution::new(4, 8))
            .workers(32)
            .build()
            .unwrap();
        assert!(!off.serve.autoscale.enabled);
    }

    #[test]
    fn telemetry_builder_paths() {
        let spec = DeploymentSpec::builder("t")
            .fc("f", 4, 10, Resolution::new(4, 8))
            .telemetry_enabled(true)
            .tracing(8)
            .build()
            .unwrap();
        assert!(spec.telemetry.enabled);
        assert!(spec.telemetry.trace);
        assert_eq!(spec.telemetry.trace_sample, 8);
        assert_eq!(spec.telemetry.flight_capacity, 256);
        // A plain spec keeps everything off.
        let plain = DeploymentSpec::builder("t")
            .fc("f", 4, 10, Resolution::new(4, 8))
            .build()
            .unwrap();
        assert_eq!(plain.telemetry, TelemetrySpec::default());
    }

    #[test]
    fn precision_builder_paths() {
        let spec = DeploymentSpec::builder("t")
            .fc("f", 4, 10, Resolution::new(4, 8))
            .adaptive_precision(8.0, 2)
            .build()
            .unwrap();
        assert!(spec.precision.enabled);
        assert_eq!(spec.precision.max_delta, 2);
        assert!((spec.precision.drop_p99_ms - 8.0).abs() < 1e-12);
        // The untouched knobs stay at their defaults.
        assert_eq!(spec.precision.queue_high, 8);
        assert_eq!(spec.precision.min_windows, 2);
        // A plain spec keeps the controller off.
        let plain = DeploymentSpec::builder("t")
            .fc("f", 4, 10, Resolution::new(4, 8))
            .build()
            .unwrap();
        assert_eq!(plain.precision, PrecisionSpec::default());
    }

    #[test]
    fn fleet_builder_paths() {
        let spec = DeploymentSpec::builder("t")
            .fc("f", 4, 10, Resolution::new(4, 8))
            .fleet_nodes(4)
            .fleet_autoscale(6, 8)
            .build()
            .unwrap();
        assert_eq!(spec.fleet.nodes, 4);
        assert_eq!(spec.fleet.max_nodes, 8);
        assert_eq!(spec.fleet.scale_high_sessions, 6);
        // The untouched knobs stay at their defaults.
        assert_eq!(spec.fleet.placement, Placement::Replicated);
        assert_eq!(spec.fleet.vnodes, 16);
        // A plain spec is a single node with autoscale off.
        let plain = DeploymentSpec::builder("t")
            .fc("f", 4, 10, Resolution::new(4, 8))
            .build()
            .unwrap();
        assert_eq!(plain.fleet, FleetSpec::default());
        assert_eq!(plain.fleet.nodes, 1);
        assert_eq!(plain.fleet.max_nodes, 0);
    }

    #[test]
    fn placement_keys_round_trip() {
        for p in [Placement::Replicated, Placement::LayerSharded] {
            assert_eq!(Placement::parse(p.key()).unwrap(), p);
        }
        assert!(Placement::parse("sharded").is_err());
    }

    #[test]
    fn input_shape_reported() {
        let spec = DeploymentSpec::builder("t")
            .conv("C1", 2, 4, 3, 4, 1, 48, 48, Resolution::new(4, 9))
            .fc("F1", 4 * 12 * 12, 10, Resolution::new(5, 10))
            .build()
            .unwrap();
        assert_eq!(spec.network.input_shape().unwrap(), (2, 48, 48));
    }
}
