//! Shipped deployment presets.
//!
//! A preset names a known-good network topology (and, via
//! [`spec`], a full default deployment around it). Presets are referenced
//! from TOML (`network.preset = "..."`) and from the CLI defaults; the
//! matching config files under `configs/` at the repo root are generated
//! from these and pinned equal by `rust/tests/integration_deploy.rs`.

use crate::snn::network::scnn_dvs_gesture;
use crate::snn::{LayerSpec, Network, Resolution};

use super::spec::DeploymentSpec;

/// Preset key of the paper's six-conv + three-FC SCNN (Fig. 4a).
pub const SCNN_DVS_GESTURE: &str = "scnn-dvs-gesture";

/// Preset key of the compact streaming demo network.
pub const SERVE_DEMO: &str = "serve-demo";

/// Preset key of the scale-out fleet demo: the serve-demo network
/// replicated over a 4-node fleet with autoscale headroom to 8.
pub const FLEET_DEMO: &str = "fleet-demo";

/// All preset keys, for error messages and sweep drivers.
pub fn names() -> Vec<&'static str> {
    vec![SCNN_DVS_GESTURE, SERVE_DEMO, FLEET_DEMO]
}

/// Compact serve demo net: 16 timesteps over the 48×48 substrate, so each
/// 100-ms session streams as 4 micro-windows of 4 frames. Defined once
/// here (it used to live in `main.rs`) and reachable from benches, tests,
/// and the TOML preset alike.
pub fn serve_demo_net() -> Network {
    let r = Resolution::new(4, 9);
    Network::new(
        "serve-demo",
        vec![
            LayerSpec::conv("C1", 2, 8, 3, 4, 1, 48, 48, r),
            LayerSpec::fc("F1", 8 * 12 * 12, 64, r),
            LayerSpec::fc("F2", 64, 10, Resolution::new(5, 10)),
        ],
        16,
    )
}

/// The network behind a preset key, if known.
pub fn network(name: &str) -> Option<Network> {
    match name {
        SCNN_DVS_GESTURE => Some(scnn_dvs_gesture()),
        // The fleet demo scales the serve-demo workload out; the
        // per-node network is the same.
        SERVE_DEMO | FLEET_DEMO => Some(serve_demo_net()),
        _ => None,
    }
}

/// A full default deployment spec around a preset network (nominal
/// substrate, native backend seeded at 42, nominal serve settings), if
/// the key is known. The fleet preset adds its `[fleet]` section on top.
pub fn spec(name: &str) -> Option<DeploymentSpec> {
    let net = network(name)?;
    let builder = DeploymentSpec::builder(&net.name).network(&net);
    let builder = if name == FLEET_DEMO {
        builder.fleet_nodes(4).fleet_autoscale(6, 8)
    } else {
        builder
    };
    Some(builder.build().expect("preset networks are valid"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve_and_validate() {
        for name in names() {
            let net = network(name).expect("known preset");
            assert!(!net.layers.is_empty());
            let spec = spec(name).expect("known preset");
            spec.validate().expect("preset specs are valid");
            assert_eq!(spec.network.name, net.name);
            assert_eq!(spec.network.layers.len(), net.layers.len());
        }
        assert!(network("nope").is_none());
        assert!(spec("nope").is_none());
    }

    #[test]
    fn serve_demo_shape_chains() {
        let net = serve_demo_net();
        assert_eq!(net.layers[0].out_shape(), (8, 12, 12));
        assert_eq!(net.layers[2].out_shape(), (10, 1, 1));
        assert_eq!(net.timesteps, 16);
    }
}
