//! Materializing runtime tiers from a validated [`DeploymentSpec`].
//!
//! [`Deployment`] owns everything every tier shares — the built
//! [`Network`], the [`SamplePlan`] (mapping, schedule, calibrated energy
//! and shard ledgers), and one [`AdjacencyCache`] — so
//! [`Deployment::coordinator`], [`Deployment::engine`], and
//! [`Deployment::service`] are cheap views over the same deployment
//! rather than three independent constructions. All backends a deployment
//! hands out (one per engine/serve worker, via [`Deployment::backend_factory`])
//! share the conv-adjacency cache.

use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, ensure};

use crate::coordinator::engine::{BackendFactory, Engine, SamplePlan};
use crate::coordinator::Coordinator;
use crate::energy::{SystemConfig, SystemEnergyModel};
use crate::runtime::{artifacts_dir, NativeScnn, Runtime, ScnnRunner, StepBackend};
use crate::serve::{AutoscaleConfig, PrecisionConfig, ServiceConfig, StreamingService};
use crate::snn::events::AdjacencyCache;
use crate::snn::{LayerKind, Network};
use crate::telemetry::TelemetryConfig;
use crate::Result;

use super::spec::{BackendSpec, DeploymentSpec};

/// A materialized deployment: the shared plan plus factories for every
/// tier. Obtained from [`DeploymentSpec::deploy`].
pub struct Deployment {
    spec: DeploymentSpec,
    net: Network,
    plan: Arc<SamplePlan>,
    adjacency: Arc<AdjacencyCache>,
}

impl DeploymentSpec {
    /// Validate the spec and build the shared deployment state (network,
    /// mapping, schedule, energy model, shard calibration). Cheap tiers
    /// ([`Deployment::coordinator`] / [`Deployment::engine`] /
    /// [`Deployment::service`]) materialize from the result on demand.
    pub fn deploy(self) -> Result<Deployment> {
        self.validate()?;
        // Process-global switches are one-way: deploying a telemetry-enabled
        // spec turns collection on, deploying a plain one never turns it
        // back off under a concurrently-observed deployment.
        if self.telemetry.enabled {
            crate::telemetry::set_enabled(true);
        }
        if self.telemetry.trace {
            crate::telemetry::trace::set_tracing(true, self.telemetry.trace_sample);
        }
        let net = self.network.build()?;
        let mut cfg = SystemConfig::flexspim(self.substrate.macros);
        cfg.vdd = self.substrate.vdd;
        let plan = Arc::new(SamplePlan::with_energy(
            net.clone(),
            self.substrate.macros,
            self.substrate.policy,
            SystemEnergyModel::new(cfg),
        ));
        Ok(Deployment {
            spec: self,
            net,
            plan,
            adjacency: Arc::new(AdjacencyCache::new()),
        })
    }
}

/// The PJRT artifacts implement one fixed topology; reject a spec whose
/// network does not match it shape-for-shape.
fn ensure_backend_matches(spec_net: &Network, have: &Network) -> Result<()> {
    let matches = have.layers.len() == spec_net.layers.len()
        && have.timesteps == spec_net.timesteps
        && have
            .layers
            .iter()
            .zip(&spec_net.layers)
            .all(|(a, b)| a.in_shape() == b.in_shape() && a.out_shape() == b.out_shape());
    ensure!(
        matches,
        "the PJRT artifacts implement '{}' ({} layers, {} timesteps) but the spec \
         describes '{}' ({} layers, {} timesteps) — use the scnn-dvs-gesture preset \
         with the pjrt backend, or a native backend for custom topologies",
        have.name,
        have.layers.len(),
        have.timesteps,
        spec_net.name,
        spec_net.layers.len(),
        spec_net.timesteps,
    );
    Ok(())
}

impl Deployment {
    /// The spec this deployment was materialized from.
    pub fn spec(&self) -> &DeploymentSpec {
        &self.spec
    }

    /// The validated workload.
    pub fn network(&self) -> &Network {
        &self.net
    }

    /// The shared per-sample plan (mapping, schedule, energy, shard
    /// ledgers) every tier executes against.
    pub fn plan(&self) -> &Arc<SamplePlan> {
        &self.plan
    }

    /// The conv-adjacency cache shared by every backend this deployment
    /// hands out.
    pub fn adjacency_cache(&self) -> &Arc<AdjacencyCache> {
        &self.adjacency
    }

    /// Construct one backend instance per the spec's backend section.
    pub fn backend(&self) -> Result<Box<dyn StepBackend>> {
        match &self.spec.backend {
            BackendSpec::Native { seed } => Ok(Box::new(NativeScnn::with_adjacency_cache(
                self.net.clone(),
                *seed,
                self.adjacency.clone(),
            ))),
            BackendSpec::NativeDense { seed } => {
                Ok(Box::new(NativeScnn::new_dense_reference(self.net.clone(), *seed)))
            }
            BackendSpec::Pjrt { artifacts } => {
                let dir = artifacts.clone().unwrap_or_else(artifacts_dir);
                let rt = Runtime::cpu()?;
                let runner = ScnnRunner::load(&rt, &dir)?;
                ensure_backend_matches(&self.net, runner.network())?;
                Ok(Box::new(runner))
            }
        }
    }

    /// A factory constructing one backend per worker thread (engine and
    /// serve pools). Native backends share this deployment's adjacency
    /// cache; the PJRT runner is `Rc`-based and not `Send`, so each worker
    /// loads its own runner inside its thread.
    pub fn backend_factory(&self) -> Arc<BackendFactory> {
        match &self.spec.backend {
            BackendSpec::Native { seed } => {
                let net = self.net.clone();
                let seed = *seed;
                let adj = self.adjacency.clone();
                Arc::new(move || {
                    Ok(Box::new(NativeScnn::with_adjacency_cache(
                        net.clone(),
                        seed,
                        adj.clone(),
                    )) as Box<dyn StepBackend>)
                })
            }
            BackendSpec::NativeDense { seed } => {
                let net = self.net.clone();
                let seed = *seed;
                Arc::new(move || {
                    Ok(Box::new(NativeScnn::new_dense_reference(net.clone(), seed))
                        as Box<dyn StepBackend>)
                })
            }
            BackendSpec::Pjrt { artifacts } => {
                let dir = artifacts.clone().unwrap_or_else(artifacts_dir);
                let net = self.net.clone();
                Arc::new(move || {
                    let rt = Runtime::cpu()?;
                    let runner = ScnnRunner::load(&rt, &dir)?;
                    ensure_backend_matches(&net, runner.network())?;
                    Ok(Box::new(runner) as Box<dyn StepBackend>)
                })
            }
        }
    }

    /// The sequential end-to-end coordinator over one backend instance.
    pub fn coordinator(&self) -> Result<Coordinator> {
        Ok(Coordinator::from_plan(self.backend()?, (*self.plan).clone()))
    }

    /// The batched parallel engine (`serve.workers` worker threads, each
    /// with its own backend from [`Self::backend_factory`]).
    pub fn engine(&self) -> Result<Engine> {
        Ok(Engine::new(
            self.plan.clone(),
            self.backend_factory(),
            self.spec.serve.workers,
        ))
    }

    /// The serve-tier configuration derived from the spec: pool size,
    /// queue bounds, residency budget, admission mode, early exit, with
    /// the session sensor dimensions taken from the network's input layer
    /// and the session clock from the network's timestep count.
    pub fn service_config(&self) -> Result<ServiceConfig> {
        let s = &self.spec.serve;
        let mut cfg = ServiceConfig::nominal(s.workers);
        cfg.queue_capacity = s.queue_capacity;
        cfg.per_session_capacity = s.per_session_capacity;
        cfg.resident_budget_bits = s.resident_budget_kb * 1024 * 8;
        cfg.deterministic_admission = s.deterministic_admission;
        cfg.early_exit_margin = s.early_exit_margin;
        cfg.early_exit_min_windows = s.early_exit_min_windows;
        cfg.telemetry = TelemetryConfig {
            enabled: self.spec.telemetry.enabled,
            flight_capacity: self.spec.telemetry.flight_capacity,
        };
        // Session clock: the serve substrate streams 100-ms gesture
        // sessions; spreading them over the spec's `timesteps` makes the
        // streamed frame grid match the offline encoder's binning, so all
        // three tiers of one deployment integrate the same frame count
        // (timesteps = 16 reproduces the historical 6.25-ms default).
        const GESTURE_SESSION_US: u64 = 100_000;
        cfg.session.step_us = (GESTURE_SESSION_US / self.net.timesteps as u64).max(1);
        cfg.session.frames_per_window = self.net.timesteps.min(4);
        // Spec overrides replace the derived clock (harness sweeps, slow
        // sensors); the reorder slack tracks whichever step wins.
        if let Some(step) = s.step_us {
            cfg.session.step_us = step;
        }
        if let Some(frames) = s.frames_per_window {
            cfg.session.frames_per_window = frames;
        }
        cfg.session.max_lateness_us = cfg.session.step_us * 2;
        let a = &s.autoscale;
        cfg.autoscale = AutoscaleConfig {
            enabled: a.enabled,
            min_workers: a.min_workers,
            max_workers: a.max_workers,
            slo_p99_s: a.slo_p99_ms * 1e-3,
            interval: Duration::from_millis(a.interval_ms),
            queue_high: a.queue_high,
            hysteresis_ticks: a.hysteresis_ticks,
        };
        let p = &self.spec.precision;
        cfg.precision = PrecisionConfig {
            enabled: p.enabled,
            max_delta: p.max_delta,
            drop_p99_s: p.drop_p99_ms * 1e-3,
            queue_high: p.queue_high,
            raise_margin: p.raise_margin,
            min_windows: p.min_windows,
        };
        match self.net.layers[0].kind {
            LayerKind::Conv { in_ch, in_h, in_w, .. } if in_ch == 2 => {
                ensure!(
                    in_h <= u16::MAX as usize && in_w <= u16::MAX as usize,
                    "serve: sensor {in_w}x{in_h} exceeds the DVS address range"
                );
                cfg.session.width = in_w as u16;
                cfg.session.height = in_h as u16;
            }
            _ => bail!(
                "serve: the streaming tier ingests DVS events, so the network's first \
                 layer must be a conv over 2 polarity channels (got {})",
                self.net.layers[0].name
            ),
        }
        Ok(cfg)
    }

    /// The streaming inference service over the spec's serve settings.
    pub fn service(&self) -> Result<StreamingService> {
        Ok(StreamingService::new(
            self.plan.clone(),
            self.backend_factory(),
            self.service_config()?,
        ))
    }

    /// A serving fleet over the spec's `[fleet]` settings: `fleet.nodes`
    /// live replicas of this deployment's service (plus autoscale
    /// standbys), each built from the shared plan and backend factory so
    /// weights are identical fleet-wide — the precondition for
    /// bit-identical session migration.
    pub fn fleet(&self) -> Result<crate::fleet::Fleet> {
        crate::fleet::Fleet::new(
            self.plan.clone(),
            self.backend_factory(),
            self.service_config()?,
            self.spec.fleet.clone(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::Policy;
    use crate::events::{GestureClass, GestureGenerator};
    use crate::snn::Resolution;
    use crate::util::rng::Rng;

    fn small_spec() -> DeploymentSpec {
        DeploymentSpec::builder("handle-test")
            .timesteps(4)
            .conv("C1", 2, 4, 3, 4, 1, 48, 48, Resolution::new(4, 9))
            .fc("F1", 4 * 12 * 12, 10, Resolution::new(5, 10))
            .macros(2)
            .policy(Policy::HsOpt)
            .native_backend(5)
            .workers(2)
            .build()
            .unwrap()
    }

    #[test]
    fn one_spec_materializes_every_tier() {
        let dep = small_spec().deploy().unwrap();
        assert_eq!(dep.network().layers.len(), 2);
        assert_eq!(dep.plan().mapping.assignments.len(), 2);

        let mut coord = dep.coordinator().unwrap();
        let engine = dep.engine().unwrap();
        let svc = dep.service().unwrap();
        assert_eq!(engine.workers(), 2);
        assert_eq!(svc.config().workers, 2);
        assert_eq!(svc.config().session.width, 48);
        // 4 timesteps over a 100-ms session: 25-ms steps, one 4-frame
        // window — the serve tier integrates the same frame count per
        // session as the offline tiers do per sample.
        assert_eq!(svc.config().session.step_us, 25_000);
        assert_eq!(svc.config().session.frames_per_window, 4);

        let gen = GestureGenerator::default_48();
        let mut rng = Rng::new(3);
        let s = gen.sample(GestureClass::ArmRoll, &mut rng);
        let r = coord.run_sample(&s, Some(7)).unwrap();
        assert!(r.prediction < 10);
        assert!(r.metrics.sops > 0);
    }

    #[test]
    fn coordinator_and_engine_agree_from_one_spec() {
        let dep = small_spec().deploy().unwrap();
        let gen = GestureGenerator::default_48();
        let mut rng = Rng::new(11);
        let data: Vec<_> = (0..3)
            .map(|i| (gen.sample(GestureClass::ALL[i % 10], &mut rng), i % 10))
            .collect();
        let mut coord = dep.coordinator().unwrap();
        let seq = coord.run_dataset(&data).unwrap();
        let batch = dep.engine().unwrap().run_batch(&data).unwrap();
        assert_eq!(seq.sops, batch.metrics.sops);
        assert_eq!(seq.cim, batch.metrics.cim);
        assert_eq!(seq.correct, batch.metrics.correct);
    }

    #[test]
    fn factory_workers_share_the_adjacency_cache() {
        let dep = small_spec().deploy().unwrap();
        let factory = dep.backend_factory();
        let make: &BackendFactory = factory.as_ref();
        let _a = make().unwrap();
        let _b = make().unwrap();
        assert_eq!(dep.adjacency_cache().len(), 1, "one conv geometry");
        assert!(
            dep.adjacency_cache().hits() >= 1,
            "the second worker must reuse the first worker's table"
        );
    }

    #[test]
    fn vdd_flows_into_the_energy_model() {
        let mut spec = small_spec();
        spec.substrate.vdd = 0.9;
        let dep = spec.deploy().unwrap();
        assert_eq!(dep.plan().energy.cfg.vdd, 0.9);
        let nominal = small_spec().deploy().unwrap();
        assert!(
            dep.plan().energy.sop_pj(4, 9, None) < nominal.plan().energy.sop_pj(4, 9, None),
            "low-voltage SOPs must price cheaper"
        );
    }

    #[test]
    fn clock_override_and_autoscale_reach_the_service_config() {
        let mut spec = small_spec();
        spec.serve.step_us = Some(12_500);
        spec.serve.frames_per_window = Some(2);
        spec.serve.autoscale.enabled = true;
        spec.serve.autoscale.max_workers = 8;
        spec.serve.autoscale.slo_p99_ms = 5.0;
        let cfg = spec.deploy().unwrap().service_config().unwrap();
        assert_eq!(cfg.session.step_us, 12_500, "override beats the derived clock");
        assert_eq!(cfg.session.frames_per_window, 2);
        assert_eq!(
            cfg.session.max_lateness_us, 25_000,
            "reorder slack tracks the overridden step"
        );
        assert!(cfg.autoscale.enabled);
        assert_eq!(cfg.autoscale.max_workers, 8);
        assert!((cfg.autoscale.slo_p99_s - 0.005).abs() < 1e-12);
        assert_eq!(cfg.autoscale.interval, Duration::from_millis(10));
    }

    #[test]
    fn telemetry_spec_reaches_the_service_config() {
        let mut spec = small_spec();
        spec.telemetry.enabled = true;
        spec.telemetry.flight_capacity = 32;
        let cfg = spec.deploy().unwrap().service_config().unwrap();
        assert!(cfg.telemetry.enabled);
        assert_eq!(cfg.telemetry.flight_capacity, 32);
        // A plain spec keeps the service instrumentation off.
        let cfg = small_spec().deploy().unwrap().service_config().unwrap();
        assert!(!cfg.telemetry.enabled);
    }

    #[test]
    fn precision_spec_reaches_the_service_config() {
        let mut spec = small_spec();
        spec.precision.enabled = true;
        spec.precision.max_delta = 2;
        spec.precision.drop_p99_ms = 5.0;
        spec.precision.raise_margin = 0.3;
        let cfg = spec.deploy().unwrap().service_config().unwrap();
        assert!(cfg.precision.enabled);
        assert_eq!(cfg.precision.max_delta, 2);
        assert!((cfg.precision.drop_p99_s - 0.005).abs() < 1e-12, "ms converts to s");
        assert!((cfg.precision.raise_margin - 0.3).abs() < 1e-12);
        // A plain spec keeps the controller off.
        let cfg = small_spec().deploy().unwrap().service_config().unwrap();
        assert!(!cfg.precision.enabled);
    }

    #[test]
    fn fleet_section_materializes_a_fleet() {
        let mut spec = small_spec();
        spec.fleet.nodes = 2;
        let dep = spec.deploy().unwrap();
        let fleet = dep.fleet().unwrap();
        assert_eq!(fleet.live_nodes(), vec![0, 1]);
        assert_eq!(
            fleet.ledger().weight_push_bits,
            2 * dep.network().total_weight_bits(),
            "boot joins broadcast the weight image to each replica"
        );
        // Replicas inherit the deployment's serve config.
        assert_eq!(fleet.node(0).config().session.width, 48);
    }

    #[test]
    fn fc_first_network_cannot_serve() {
        let spec = DeploymentSpec::builder("fc-only")
            .fc("F1", 32, 10, Resolution::new(4, 8))
            .build()
            .unwrap();
        let dep = spec.deploy().unwrap();
        let err = dep.service_config().unwrap_err();
        assert!(format!("{err}").contains("polarity"), "got: {err}");
        // The offline tiers still work.
        assert!(dep.coordinator().is_ok());
    }
}
