//! Typed config for the non-deployment subcommands: `[train]` and
//! `[simulate]`.
//!
//! `flexspim train` and `flexspim simulate` do not build a
//! [`super::DeploymentSpec`] — training drives the AOT gradient
//! artifacts and `simulate` exercises one bare CIM macro — but their
//! knobs deserve the same config story as the deployment tiers: a TOML
//! file with strict parsing (unknown keys are errors, via the shared
//! [`super::toml::StrictDoc`]) plus CLI-flag overlays, instead of raw
//! flags only.
//!
//! ## Format
//!
//! ```toml
//! [train]
//! steps = 100                # optional (defaults shown)
//! lr = 0.05
//! seed = 42
//! out = "artifacts/weights_trained.bin"
//!
//! [simulate]
//! w_bits = 8
//! p_bits = 16
//! n_c = 1
//! neurons = 32
//! fan_in = 4
//! ```
//!
//! Both sections are optional; a missing section means its defaults.
//! [`TrainSpec::to_toml`] is the lossless inverse of
//! [`TrainSpec::from_toml_str`].

use std::fmt::Write as _;
use std::path::Path;

use anyhow::{anyhow, ensure};

use crate::config::toml_lite::Doc;
use crate::Result;

use super::toml::StrictDoc;

/// `[train]` section: the supervised training loop's knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainConfig {
    /// Gradient steps to run.
    pub steps: usize,
    /// Learning rate.
    pub lr: f32,
    /// Data/shuffle seed.
    pub seed: u64,
    /// Output path for the trained FSPW weight file.
    pub out: String,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 100,
            lr: 0.05,
            seed: 42,
            out: "artifacts/weights_trained.bin".to_string(),
        }
    }
}

/// `[simulate]` section: the bare-macro demo's shape and resolution.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SimulateConfig {
    /// Weight resolution in bits.
    pub w_bits: u32,
    /// Membrane-potential resolution in bits.
    pub p_bits: u32,
    /// Operand columns N_C.
    pub n_c: u32,
    /// Parallel neurons in the macro.
    pub neurons: usize,
    /// Synapses per neuron.
    pub fan_in: usize,
}

impl Default for SimulateConfig {
    fn default() -> Self {
        SimulateConfig { w_bits: 8, p_bits: 16, n_c: 1, neurons: 32, fan_in: 4 }
    }
}

/// The typed `[train]`/`[simulate]` config file.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TrainSpec {
    /// Training-loop settings.
    pub train: TrainConfig,
    /// Bare-macro demo settings.
    pub simulate: SimulateConfig,
}

impl TrainSpec {
    /// Parse from TOML text (strict: unknown keys are errors).
    pub fn from_toml_str(text: &str) -> Result<TrainSpec> {
        let doc = Doc::parse(text).map_err(|e| anyhow!("TOML parse error: {e}"))?;
        let mut t = StrictDoc::new(&doc);

        let mut train = TrainConfig::default();
        if let Some(s) = t.take_usize("train.steps")? {
            train.steps = s;
        }
        if let Some(lr) = t.take_float("train.lr")? {
            train.lr = lr as f32;
        }
        if let Some(s) = t.take_u64("train.seed")? {
            train.seed = s;
        }
        if let Some(o) = t.take_str("train.out")? {
            train.out = o;
        }

        let mut simulate = SimulateConfig::default();
        if let Some(b) = t.take_u32("simulate.w_bits")? {
            simulate.w_bits = b;
        }
        if let Some(b) = t.take_u32("simulate.p_bits")? {
            simulate.p_bits = b;
        }
        if let Some(n) = t.take_u32("simulate.n_c")? {
            simulate.n_c = n;
        }
        if let Some(n) = t.take_usize("simulate.neurons")? {
            simulate.neurons = n;
        }
        if let Some(f) = t.take_usize("simulate.fan_in")? {
            simulate.fan_in = f;
        }

        t.finish()?;
        let spec = TrainSpec { train, simulate };
        spec.validate()?;
        Ok(spec)
    }

    /// Load from a TOML file.
    pub fn load(path: &Path) -> Result<TrainSpec> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("config {}: {e}", path.display()))?;
        Self::from_toml_str(&text).map_err(|e| anyhow!("{}: {e}", path.display()))
    }

    /// Sanity limits for both sections.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.train.steps >= 1, "train: steps must be >= 1");
        ensure!(
            self.train.lr.is_finite() && self.train.lr > 0.0,
            "train: lr {} must be a positive finite number",
            self.train.lr
        );
        ensure!(!self.train.out.is_empty(), "train: out path must not be empty");
        let s = &self.simulate;
        ensure!(s.w_bits >= 1, "simulate: w_bits must be >= 1");
        ensure!(s.p_bits >= 1, "simulate: p_bits must be >= 1");
        ensure!(s.n_c >= 1, "simulate: n_c must be >= 1");
        ensure!(s.neurons >= 1, "simulate: neurons must be >= 1");
        ensure!(s.fan_in >= 1, "simulate: fan_in must be >= 1");
        Ok(())
    }

    /// Serialize to TOML; `from_toml_str(to_toml(spec)) == spec`.
    pub fn to_toml(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "[train]");
        let _ = writeln!(out, "steps = {}", self.train.steps);
        let _ = writeln!(out, "lr = {}", self.train.lr);
        let _ = writeln!(out, "seed = {}", self.train.seed);
        let _ = writeln!(out, "out = \"{}\"", self.train.out);
        out.push('\n');
        let _ = writeln!(out, "[simulate]");
        let _ = writeln!(out, "w_bits = {}", self.simulate.w_bits);
        let _ = writeln!(out, "p_bits = {}", self.simulate.p_bits);
        let _ = writeln!(out, "n_c = {}", self.simulate.n_c);
        let _ = writeln!(out, "neurons = {}", self.simulate.neurons);
        let _ = writeln!(out, "fan_in = {}", self.simulate.fan_in);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_round_trip() {
        let spec = TrainSpec::default();
        let text = spec.to_toml();
        let parsed = TrainSpec::from_toml_str(&text).unwrap();
        assert_eq!(parsed, spec);
        assert_eq!(parsed.to_toml(), text, "serialization is a fixed point");
        // An empty document is all defaults.
        assert_eq!(TrainSpec::from_toml_str("").unwrap(), TrainSpec::default());
    }

    #[test]
    fn sections_parse_and_stay_strict() {
        let spec = TrainSpec::from_toml_str(
            "[train]\nsteps = 7\nlr = 0.125\nout = \"w.bin\"\n\
             [simulate]\nw_bits = 4\nneurons = 8\n",
        )
        .unwrap();
        assert_eq!(spec.train.steps, 7);
        assert!((spec.train.lr - 0.125).abs() < 1e-9);
        assert_eq!(spec.train.out, "w.bin");
        assert_eq!(spec.train.seed, 42, "unset keys keep defaults");
        assert_eq!((spec.simulate.w_bits, spec.simulate.neurons), (4, 8));
        let err = TrainSpec::from_toml_str("[train]\nstep = 7\n").unwrap_err();
        assert!(format!("{err}").contains("train.step"), "got: {err}");
    }

    #[test]
    fn invalid_values_rejected() {
        assert!(TrainSpec::from_toml_str("[train]\nsteps = 0\n").is_err());
        assert!(TrainSpec::from_toml_str("[train]\nlr = 0\n").is_err());
        assert!(TrainSpec::from_toml_str("[simulate]\nfan_in = 0\n").is_err());
    }
}
