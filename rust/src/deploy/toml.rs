//! TOML (de)serialization of [`DeploymentSpec`].
//!
//! Built on [`crate::config::toml_lite`] (serde is not vendored offline).
//! Parsing is *strict*: unknown keys and wrongly-typed values are errors,
//! not silently ignored — a typo in a config file fails fast with the
//! offending key named. Serialization ([`DeploymentSpec::to_toml`]) emits
//! explicit `[layer.N]` tables (presets are resolved at load time), so
//! `parse(to_toml(spec)) == spec` for every valid spec.
//!
//! ## Format
//!
//! ```toml
//! [network]
//! name = "serve-demo"        # optional
//! timesteps = 16             # optional (default 16 / preset's value)
//! # EITHER a preset:
//! # preset = "serve-demo"    #   (serve-demo | scnn-dvs-gesture | ...)
//! # OR explicit layer tables:
//!
//! [layer.1]
//! type = "conv"              # conv | fc
//! name = "C1"                # optional (default "L<n>")
//! in_ch = 2
//! out_ch = 8
//! kernel = 3
//! stride = 4                 # optional (default 1)
//! pad = 1                    # optional (default 0)
//! in_h = 48
//! in_w = 48
//! w_bits = 4
//! p_bits = 9
//!
//! [layer.2]
//! type = "fc"
//! in_dim = 1152
//! out_dim = 10
//! w_bits = 5
//! p_bits = 10
//!
//! [substrate]
//! macros = 16                # optional (default 16)
//! policy = "hs-opt"          # optional (default hs-opt)
//! vdd = 1.1                  # optional (default 1.1)
//!
//! [backend]
//! kind = "native"            # native | native-dense | pjrt (default native)
//! seed = 42                  # native backends only (default 42)
//! # artifacts = "artifacts"  # pjrt only
//!
//! [serve]
//! workers = 4                # all optional; see ServeSpec for defaults
//! queue_capacity = 4096
//! per_session_capacity = 256
//! budget_kb = 0
//! deterministic = false
//! exit_margin = 0.0
//! exit_min_windows = 2
//! # step_us = 6250           # session clock override: us per SNN timestep
//! # frames_per_window = 4    #   ... and timesteps per micro-window
//! # autoscale = true         # SLO worker-pool autoscaler (default off)
//! # autoscale_min = 1        #   pool floor
//! # autoscale_max = 16       #   pool ceiling (threads spawned up front)
//! # slo_p99_ms = 20.0        #   grow when rolling p99 exceeds this
//! # autoscale_interval_ms = 10      # control-loop tick
//! # autoscale_queue_high = 8        # queued windows/worker = overloaded
//! # autoscale_hysteresis = 5        # calm ticks before one shrink step
//!
//! # [telemetry]              # whole section optional (defaults off)
//! # enabled = true           # metrics registry + flight recorder
//! # trace = false            # Chrome-trace span capture
//! # trace_sample = 64        # record 1 in N spans (>= 1)
//! # flight_capacity = 256    # flight-recorder ring size
//!
//! # [precision]              # whole section optional (defaults off)
//! # enabled = true           # per-session serve-time precision control
//! # max_delta = 3            # deepest resolution tier (1..=7)
//! # drop_p99_ms = 20.0       # rolling p99 above this drops one tier
//! # queue_high = 8           # queued windows/worker = overloaded
//! # raise_margin = 0.5       # margin below this raises one tier
//! # min_windows = 2          # windows before margin raises may fire
//!
//! # [fleet]                  # whole section optional (default: 1 node)
//! # nodes = 4                # replica nodes at boot (1..=64)
//! # placement = "replicated" # replicated | layer-sharded
//! # capacity_sessions = 0    # sticky sessions per node (0 = unbounded)
//! # vnodes = 16              # virtual nodes per node on the hash ring
//! # link_pj_per_bit = 30.0   # inter-node link energy (pJ/bit)
//! # max_nodes = 0            # autoscale-join ceiling (0 = off)
//! # scale_high_sessions = 8  # mean sessions/node that triggers a join
//! ```

use std::collections::BTreeSet;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, ensure};

use crate::config::toml_lite::{Doc, Value};
use crate::Result;

use super::presets;
use super::spec::{
    parse_policy, policy_key, AutoscaleSpec, BackendSpec, DeploymentSpec, FleetSpec, LayerDef,
    NetworkSpec, Placement, PrecisionSpec, ServeSpec, SubstrateSpec, TelemetrySpec,
};

// ------------------------------------------------------------ strict doc

/// A [`Doc`] wrapper that records every key it is asked for, so leftover
/// (unknown) keys can be rejected after parsing, and that turns
/// wrongly-typed values into errors instead of silent defaults.
///
/// Shared (`pub(crate)`) so sibling strict parsers — e.g. the `[train]`
/// config in [`super::train`] — inherit the same contract.
pub(crate) struct StrictDoc<'a> {
    doc: &'a Doc,
    used: BTreeSet<String>,
}

impl<'a> StrictDoc<'a> {
    pub(crate) fn new(doc: &'a Doc) -> StrictDoc<'a> {
        StrictDoc { doc, used: BTreeSet::new() }
    }

    fn raw(&mut self, key: &str) -> Option<&'a Value> {
        self.used.insert(key.to_string());
        self.doc.get(key)
    }

    pub(crate) fn take_str(&mut self, key: &str) -> Result<Option<String>> {
        match self.raw(key) {
            None => Ok(None),
            Some(v) => v
                .as_str()
                .map(|s| Some(s.to_string()))
                .ok_or_else(|| anyhow!("config key '{key}': expected a string")),
        }
    }

    pub(crate) fn take_int(&mut self, key: &str) -> Result<Option<i64>> {
        match self.raw(key) {
            None => Ok(None),
            Some(v) => v
                .as_int()
                .map(Some)
                .ok_or_else(|| anyhow!("config key '{key}': expected an integer")),
        }
    }

    pub(crate) fn take_float(&mut self, key: &str) -> Result<Option<f64>> {
        match self.raw(key) {
            None => Ok(None),
            Some(v) => v
                .as_float()
                .map(Some)
                .ok_or_else(|| anyhow!("config key '{key}': expected a number")),
        }
    }

    pub(crate) fn take_bool(&mut self, key: &str) -> Result<Option<bool>> {
        match self.raw(key) {
            None => Ok(None),
            Some(v) => v
                .as_bool()
                .map(Some)
                .ok_or_else(|| anyhow!("config key '{key}': expected a boolean")),
        }
    }

    pub(crate) fn take_usize(&mut self, key: &str) -> Result<Option<usize>> {
        match self.take_int(key)? {
            None => Ok(None),
            Some(i) => usize::try_from(i)
                .map(Some)
                .map_err(|_| anyhow!("config key '{key}': {i} is not a valid non-negative size")),
        }
    }

    pub(crate) fn take_u64(&mut self, key: &str) -> Result<Option<u64>> {
        match self.take_int(key)? {
            None => Ok(None),
            Some(i) => u64::try_from(i)
                .map(Some)
                .map_err(|_| anyhow!("config key '{key}': {i} must be non-negative")),
        }
    }

    pub(crate) fn take_u32(&mut self, key: &str) -> Result<Option<u32>> {
        match self.take_int(key)? {
            None => Ok(None),
            Some(i) => u32::try_from(i)
                .map(Some)
                .map_err(|_| anyhow!("config key '{key}': {i} out of range")),
        }
    }

    pub(crate) fn require_usize(&mut self, key: &str) -> Result<usize> {
        self.take_usize(key)?
            .ok_or_else(|| anyhow!("missing config key '{key}'"))
    }

    pub(crate) fn require_u32(&mut self, key: &str) -> Result<u32> {
        self.take_u32(key)?
            .ok_or_else(|| anyhow!("missing config key '{key}'"))
    }

    /// Reject any key the parser never consumed.
    pub(crate) fn finish(self) -> Result<()> {
        let unknown: Vec<&str> = self
            .doc
            .keys_under("")
            .into_iter()
            .filter(|k| !self.used.contains(*k))
            .collect();
        ensure!(
            unknown.is_empty(),
            "unknown config key(s): {} (strict parsing — check for typos)",
            unknown.join(", ")
        );
        Ok(())
    }
}

// ---------------------------------------------------------------- parsing

/// The `[layer.N]` indices present in the document, validated to be the
/// contiguous run `1..=n`.
fn layer_indices(doc: &Doc) -> Result<Vec<usize>> {
    let mut seen = BTreeSet::new();
    for key in doc.keys_under("layer.") {
        let rest = &key["layer.".len()..];
        let idx_str = rest
            .split('.')
            .next()
            .filter(|s| !s.is_empty())
            .ok_or_else(|| anyhow!("malformed layer key '{key}'"))?;
        let idx: usize = idx_str
            .parse()
            .map_err(|_| anyhow!("malformed layer table '[layer.{idx_str}]': not a number"))?;
        ensure!(idx >= 1, "layer tables are numbered from 1, found [layer.{idx}]");
        seen.insert(idx);
    }
    let idxs: Vec<usize> = seen.into_iter().collect();
    for (pos, &idx) in idxs.iter().enumerate() {
        ensure!(
            idx == pos + 1,
            "layer tables must be contiguous from [layer.1]: missing [layer.{}]",
            pos + 1
        );
    }
    Ok(idxs)
}

fn parse_layer(t: &mut StrictDoc<'_>, idx: usize) -> Result<LayerDef> {
    let p = format!("layer.{idx}");
    let ty = t
        .take_str(&format!("{p}.type"))?
        .ok_or_else(|| anyhow!("[{p}]: missing 'type' (conv|fc)"))?;
    let name = t
        .take_str(&format!("{p}.name"))?
        .unwrap_or_else(|| format!("L{idx}"));
    let w_bits = t.require_u32(&format!("{p}.w_bits"))?;
    let p_bits = t.require_u32(&format!("{p}.p_bits"))?;
    match ty.as_str() {
        "conv" => Ok(LayerDef::Conv {
            name,
            in_ch: t.require_usize(&format!("{p}.in_ch"))?,
            out_ch: t.require_usize(&format!("{p}.out_ch"))?,
            k: t.require_usize(&format!("{p}.kernel"))?,
            stride: t.take_usize(&format!("{p}.stride"))?.unwrap_or(1),
            pad: t.take_usize(&format!("{p}.pad"))?.unwrap_or(0),
            in_h: t.require_usize(&format!("{p}.in_h"))?,
            in_w: t.require_usize(&format!("{p}.in_w"))?,
            w_bits,
            p_bits,
        }),
        "fc" => Ok(LayerDef::Fc {
            name,
            in_dim: t.require_usize(&format!("{p}.in_dim"))?,
            out_dim: t.require_usize(&format!("{p}.out_dim"))?,
            w_bits,
            p_bits,
        }),
        other => bail!("[{p}]: unknown layer type '{other}' (conv|fc)"),
    }
}

fn parse_network(t: &mut StrictDoc<'_>, layer_idxs: &[usize]) -> Result<NetworkSpec> {
    let preset = t.take_str("network.preset")?;
    let name = t.take_str("network.name")?;
    let timesteps = t.take_usize("network.timesteps")?;
    match (preset, layer_idxs.is_empty()) {
        (Some(p), true) => {
            let net = presets::network(&p).ok_or_else(|| {
                anyhow!(
                    "unknown network preset '{p}' (known: {})",
                    presets::names().join(", ")
                )
            })?;
            let mut spec = NetworkSpec::from_network(&net);
            if let Some(n) = name {
                spec.name = n;
            }
            if let Some(ts) = timesteps {
                spec.timesteps = ts;
            }
            Ok(spec)
        }
        (None, false) => {
            let mut spec = NetworkSpec::new(
                name.as_deref().unwrap_or("custom"),
                timesteps.unwrap_or(16),
            );
            for &idx in layer_idxs {
                spec.layers.push(parse_layer(t, idx)?);
            }
            Ok(spec)
        }
        (Some(_), false) => {
            bail!("config sets both network.preset and [layer.N] tables — pick one")
        }
        (None, true) => {
            bail!("config needs a topology: either network.preset or [layer.N] tables")
        }
    }
}

fn parse_backend(t: &mut StrictDoc<'_>) -> Result<BackendSpec> {
    let kind = t.take_str("backend.kind")?.unwrap_or_else(|| "native".into());
    let seed = t.take_u64("backend.seed")?;
    let artifacts = t.take_str("backend.artifacts")?;
    match kind.as_str() {
        "native" | "native-dense" => {
            ensure!(
                artifacts.is_none(),
                "backend.artifacts only applies to the pjrt backend"
            );
            let seed = seed.unwrap_or(42);
            Ok(if kind == "native" {
                BackendSpec::Native { seed }
            } else {
                BackendSpec::NativeDense { seed }
            })
        }
        "pjrt" => {
            ensure!(
                seed.is_none(),
                "backend.seed only applies to the native backends (pjrt weights \
                 come from the artifacts)"
            );
            Ok(BackendSpec::Pjrt { artifacts: artifacts.map(PathBuf::from) })
        }
        other => bail!("unknown backend kind '{other}' (native|native-dense|pjrt)"),
    }
}

/// Assemble a validated spec from a parsed document (strict: unknown keys
/// are errors).
pub fn spec_from_doc(doc: &Doc) -> Result<DeploymentSpec> {
    let mut t = StrictDoc::new(doc);
    let layer_idxs = layer_indices(doc)?;
    let network = parse_network(&mut t, &layer_idxs)?;

    let mut substrate = SubstrateSpec::default();
    if let Some(m) = t.take_usize("substrate.macros")? {
        substrate.macros = m;
    }
    if let Some(p) = t.take_str("substrate.policy")? {
        substrate.policy = parse_policy(&p)?;
    }
    if let Some(v) = t.take_float("substrate.vdd")? {
        substrate.vdd = v;
    }

    let backend = parse_backend(&mut t)?;

    let mut serve = ServeSpec::default();
    if let Some(w) = t.take_usize("serve.workers")? {
        serve.workers = w;
    }
    if let Some(q) = t.take_usize("serve.queue_capacity")? {
        serve.queue_capacity = q;
    }
    if let Some(q) = t.take_usize("serve.per_session_capacity")? {
        serve.per_session_capacity = q;
    }
    if let Some(b) = t.take_u64("serve.budget_kb")? {
        serve.resident_budget_kb = b;
    }
    if let Some(d) = t.take_bool("serve.deterministic")? {
        serve.deterministic_admission = d;
    }
    if let Some(m) = t.take_float("serve.exit_margin")? {
        serve.early_exit_margin = m;
    }
    if let Some(m) = t.take_u64("serve.exit_min_windows")? {
        serve.early_exit_min_windows = m;
    }
    serve.step_us = t.take_u64("serve.step_us")?;
    serve.frames_per_window = t.take_usize("serve.frames_per_window")?;
    if let Some(on) = t.take_bool("serve.autoscale")? {
        serve.autoscale.enabled = on;
    }
    if let Some(m) = t.take_usize("serve.autoscale_min")? {
        serve.autoscale.min_workers = m;
    }
    if let Some(m) = t.take_usize("serve.autoscale_max")? {
        serve.autoscale.max_workers = m;
    }
    if let Some(s) = t.take_float("serve.slo_p99_ms")? {
        serve.autoscale.slo_p99_ms = s;
    }
    if let Some(i) = t.take_u64("serve.autoscale_interval_ms")? {
        serve.autoscale.interval_ms = i;
    }
    if let Some(q) = t.take_usize("serve.autoscale_queue_high")? {
        serve.autoscale.queue_high = q;
    }
    if let Some(h) = t.take_u32("serve.autoscale_hysteresis")? {
        serve.autoscale.hysteresis_ticks = h;
    }

    let mut telemetry = TelemetrySpec::default();
    if let Some(on) = t.take_bool("telemetry.enabled")? {
        telemetry.enabled = on;
    }
    if let Some(tr) = t.take_bool("telemetry.trace")? {
        telemetry.trace = tr;
    }
    if let Some(s) = t.take_u32("telemetry.trace_sample")? {
        telemetry.trace_sample = s;
    }
    if let Some(c) = t.take_usize("telemetry.flight_capacity")? {
        telemetry.flight_capacity = c;
    }

    let mut precision = PrecisionSpec::default();
    if let Some(on) = t.take_bool("precision.enabled")? {
        precision.enabled = on;
    }
    if let Some(d) = t.take_u32("precision.max_delta")? {
        precision.max_delta = d;
    }
    if let Some(p) = t.take_float("precision.drop_p99_ms")? {
        precision.drop_p99_ms = p;
    }
    if let Some(q) = t.take_usize("precision.queue_high")? {
        precision.queue_high = q;
    }
    if let Some(m) = t.take_float("precision.raise_margin")? {
        precision.raise_margin = m;
    }
    if let Some(w) = t.take_u64("precision.min_windows")? {
        precision.min_windows = w;
    }

    let mut fleet = FleetSpec::default();
    if let Some(n) = t.take_usize("fleet.nodes")? {
        fleet.nodes = n;
    }
    if let Some(p) = t.take_str("fleet.placement")? {
        fleet.placement = Placement::parse(&p)?;
    }
    if let Some(c) = t.take_usize("fleet.capacity_sessions")? {
        fleet.capacity_sessions = c;
    }
    if let Some(v) = t.take_usize("fleet.vnodes")? {
        fleet.vnodes = v;
    }
    if let Some(e) = t.take_float("fleet.link_pj_per_bit")? {
        fleet.link_pj_per_bit = e;
    }
    if let Some(m) = t.take_usize("fleet.max_nodes")? {
        fleet.max_nodes = m;
    }
    if let Some(s) = t.take_usize("fleet.scale_high_sessions")? {
        fleet.scale_high_sessions = s;
    }

    t.finish()?;
    let spec =
        DeploymentSpec { network, substrate, backend, serve, telemetry, precision, fleet };
    spec.validate()?;
    Ok(spec)
}

// ---------------------------------------------------------- serialization

fn emit_layer(out: &mut String, idx: usize, layer: &LayerDef) {
    let _ = writeln!(out, "[layer.{idx}]");
    match layer {
        LayerDef::Conv {
            name,
            in_ch,
            out_ch,
            k,
            stride,
            pad,
            in_h,
            in_w,
            w_bits,
            p_bits,
        } => {
            let _ = writeln!(out, "type = \"conv\"");
            let _ = writeln!(out, "name = \"{name}\"");
            let _ = writeln!(out, "in_ch = {in_ch}");
            let _ = writeln!(out, "out_ch = {out_ch}");
            let _ = writeln!(out, "kernel = {k}");
            let _ = writeln!(out, "stride = {stride}");
            let _ = writeln!(out, "pad = {pad}");
            let _ = writeln!(out, "in_h = {in_h}");
            let _ = writeln!(out, "in_w = {in_w}");
            let _ = writeln!(out, "w_bits = {w_bits}");
            let _ = writeln!(out, "p_bits = {p_bits}");
        }
        LayerDef::Fc { name, in_dim, out_dim, w_bits, p_bits } => {
            let _ = writeln!(out, "type = \"fc\"");
            let _ = writeln!(out, "name = \"{name}\"");
            let _ = writeln!(out, "in_dim = {in_dim}");
            let _ = writeln!(out, "out_dim = {out_dim}");
            let _ = writeln!(out, "w_bits = {w_bits}");
            let _ = writeln!(out, "p_bits = {p_bits}");
        }
    }
    out.push('\n');
}

impl DeploymentSpec {
    /// Parse a spec from TOML text (strict: unknown keys are errors).
    pub fn from_toml_str(text: &str) -> Result<DeploymentSpec> {
        let doc = Doc::parse(text).map_err(|e| anyhow!("TOML parse error: {e}"))?;
        spec_from_doc(&doc)
    }

    /// Load a spec from a TOML file.
    pub fn load(path: &Path) -> Result<DeploymentSpec> {
        let doc = Doc::load(path).map_err(|e| anyhow!("config {e}"))?;
        spec_from_doc(&doc)
            .map_err(|e| anyhow!("{}: {e}", path.display()))
    }

    /// Serialize to TOML. Layers are always explicit `[layer.N]` tables
    /// (presets resolve at load time), so the output parses back to a
    /// spec equal to `self`.
    pub fn to_toml(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "# FlexSpIM deployment: {}", self.network.name);
        let _ = writeln!(out, "[network]");
        let _ = writeln!(out, "name = \"{}\"", self.network.name);
        let _ = writeln!(out, "timesteps = {}", self.network.timesteps);
        out.push('\n');
        for (i, layer) in self.network.layers.iter().enumerate() {
            emit_layer(&mut out, i + 1, layer);
        }
        let _ = writeln!(out, "[substrate]");
        let _ = writeln!(out, "macros = {}", self.substrate.macros);
        let _ = writeln!(out, "policy = \"{}\"", policy_key(self.substrate.policy));
        let _ = writeln!(out, "vdd = {}", self.substrate.vdd);
        out.push('\n');
        let _ = writeln!(out, "[backend]");
        let _ = writeln!(out, "kind = \"{}\"", self.backend.kind());
        match &self.backend {
            BackendSpec::Native { seed } | BackendSpec::NativeDense { seed } => {
                let _ = writeln!(out, "seed = {seed}");
            }
            BackendSpec::Pjrt { artifacts } => {
                if let Some(dir) = artifacts {
                    let _ = writeln!(out, "artifacts = \"{}\"", dir.display());
                }
            }
        }
        out.push('\n');
        let _ = writeln!(out, "[serve]");
        let _ = writeln!(out, "workers = {}", self.serve.workers);
        let _ = writeln!(out, "queue_capacity = {}", self.serve.queue_capacity);
        let _ = writeln!(
            out,
            "per_session_capacity = {}",
            self.serve.per_session_capacity
        );
        let _ = writeln!(out, "budget_kb = {}", self.serve.resident_budget_kb);
        let _ = writeln!(out, "deterministic = {}", self.serve.deterministic_admission);
        let _ = writeln!(out, "exit_margin = {}", self.serve.early_exit_margin);
        let _ = writeln!(
            out,
            "exit_min_windows = {}",
            self.serve.early_exit_min_windows
        );
        // Optional overrides are emitted only when set, so configs written
        // before these knobs existed serialize byte-identically.
        if let Some(step) = self.serve.step_us {
            let _ = writeln!(out, "step_us = {step}");
        }
        if let Some(frames) = self.serve.frames_per_window {
            let _ = writeln!(out, "frames_per_window = {frames}");
        }
        let a = &self.serve.autoscale;
        if *a != AutoscaleSpec::default() {
            let _ = writeln!(out, "autoscale = {}", a.enabled);
            let _ = writeln!(out, "autoscale_min = {}", a.min_workers);
            let _ = writeln!(out, "autoscale_max = {}", a.max_workers);
            let _ = writeln!(out, "slo_p99_ms = {}", a.slo_p99_ms);
            let _ = writeln!(out, "autoscale_interval_ms = {}", a.interval_ms);
            let _ = writeln!(out, "autoscale_queue_high = {}", a.queue_high);
            let _ = writeln!(out, "autoscale_hysteresis = {}", a.hysteresis_ticks);
        }
        let tl = &self.telemetry;
        if *tl != TelemetrySpec::default() {
            out.push('\n');
            let _ = writeln!(out, "[telemetry]");
            let _ = writeln!(out, "enabled = {}", tl.enabled);
            let _ = writeln!(out, "trace = {}", tl.trace);
            let _ = writeln!(out, "trace_sample = {}", tl.trace_sample);
            let _ = writeln!(out, "flight_capacity = {}", tl.flight_capacity);
        }
        let pr = &self.precision;
        if *pr != PrecisionSpec::default() {
            out.push('\n');
            let _ = writeln!(out, "[precision]");
            let _ = writeln!(out, "enabled = {}", pr.enabled);
            let _ = writeln!(out, "max_delta = {}", pr.max_delta);
            let _ = writeln!(out, "drop_p99_ms = {}", pr.drop_p99_ms);
            let _ = writeln!(out, "queue_high = {}", pr.queue_high);
            let _ = writeln!(out, "raise_margin = {}", pr.raise_margin);
            let _ = writeln!(out, "min_windows = {}", pr.min_windows);
        }
        let fl = &self.fleet;
        if *fl != FleetSpec::default() {
            out.push('\n');
            let _ = writeln!(out, "[fleet]");
            let _ = writeln!(out, "nodes = {}", fl.nodes);
            let _ = writeln!(out, "placement = \"{}\"", fl.placement.key());
            let _ = writeln!(out, "capacity_sessions = {}", fl.capacity_sessions);
            let _ = writeln!(out, "vnodes = {}", fl.vnodes);
            let _ = writeln!(out, "link_pj_per_bit = {}", fl.link_pj_per_bit);
            let _ = writeln!(out, "max_nodes = {}", fl.max_nodes);
            let _ = writeln!(out, "scale_high_sessions = {}", fl.scale_high_sessions);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::Policy;
    use crate::snn::Resolution;

    fn demo_spec() -> DeploymentSpec {
        DeploymentSpec::builder("toml-demo")
            .timesteps(8)
            .conv("C1", 2, 4, 3, 4, 1, 48, 48, Resolution::new(4, 9))
            .fc("F1", 4 * 12 * 12, 10, Resolution::new(5, 10))
            .macros(4)
            .policy(Policy::HsMin)
            .vdd(0.95)
            .native_backend(7)
            .workers(2)
            .resident_budget_kb(64)
            .deterministic_admission(true)
            .early_exit(0.25, 3)
            .build()
            .unwrap()
    }

    #[test]
    fn serialize_parse_round_trip() {
        let spec = demo_spec();
        let text = spec.to_toml();
        let parsed = DeploymentSpec::from_toml_str(&text).unwrap();
        assert_eq!(parsed, spec);
        // And the serialization itself is a fixed point.
        assert_eq!(parsed.to_toml(), text);
    }

    #[test]
    fn preset_reference_loads() {
        let spec = DeploymentSpec::from_toml_str(
            "[network]\npreset = \"serve-demo\"\n",
        )
        .unwrap();
        assert_eq!(spec.network.name, "serve-demo");
        assert!(!spec.network.layers.is_empty());
        // A preset-loaded spec still round-trips through explicit layers.
        let again = DeploymentSpec::from_toml_str(&spec.to_toml()).unwrap();
        assert_eq!(again, spec);
    }

    #[test]
    fn unknown_keys_rejected() {
        let err = DeploymentSpec::from_toml_str(
            "[network]\npreset = \"serve-demo\"\n[serve]\nworkerz = 4\n",
        )
        .unwrap_err();
        assert!(format!("{err}").contains("serve.workerz"), "got: {err}");
    }

    #[test]
    fn wrong_types_rejected() {
        let err = DeploymentSpec::from_toml_str(
            "[network]\npreset = \"serve-demo\"\n[serve]\nworkers = \"four\"\n",
        )
        .unwrap_err();
        assert!(format!("{err}").contains("expected an integer"), "got: {err}");
    }

    #[test]
    fn preset_and_layers_conflict() {
        let err = DeploymentSpec::from_toml_str(
            "[network]\npreset = \"serve-demo\"\n[layer.1]\ntype = \"fc\"\n\
             in_dim = 4\nout_dim = 2\nw_bits = 4\np_bits = 8\n",
        )
        .unwrap_err();
        assert!(format!("{err}").contains("pick one"), "got: {err}");
    }

    #[test]
    fn missing_topology_rejected() {
        let err = DeploymentSpec::from_toml_str("[substrate]\nmacros = 4\n").unwrap_err();
        assert!(format!("{err}").contains("topology"), "got: {err}");
    }

    #[test]
    fn non_contiguous_layers_rejected() {
        let err = DeploymentSpec::from_toml_str(
            "[layer.2]\ntype = \"fc\"\nin_dim = 4\nout_dim = 2\nw_bits = 4\np_bits = 8\n",
        )
        .unwrap_err();
        assert!(format!("{err}").contains("missing [layer.1]"), "got: {err}");
    }

    #[test]
    fn bad_policy_and_backend_rejected() {
        let base = "[network]\npreset = \"serve-demo\"\n";
        let err = DeploymentSpec::from_toml_str(
            &format!("{base}[substrate]\npolicy = \"magic\"\n"),
        )
        .unwrap_err();
        assert!(format!("{err}").contains("unknown policy"), "got: {err}");
        let err = DeploymentSpec::from_toml_str(
            &format!("{base}[backend]\nkind = \"gpu\"\n"),
        )
        .unwrap_err();
        assert!(format!("{err}").contains("unknown backend"), "got: {err}");
        let err = DeploymentSpec::from_toml_str(
            &format!("{base}[backend]\nkind = \"pjrt\"\nseed = 3\n"),
        )
        .unwrap_err();
        assert!(format!("{err}").contains("backend.seed"), "got: {err}");
    }

    #[test]
    fn clock_and_autoscale_keys_round_trip() {
        let spec = DeploymentSpec::builder("toml-auto")
            .timesteps(8)
            .conv("C1", 2, 4, 3, 4, 1, 48, 48, Resolution::new(4, 9))
            .fc("F1", 4 * 12 * 12, 10, Resolution::new(5, 10))
            .workers(2)
            .session_clock(12_500, 2)
            .autoscale_slo(5.0, 8)
            .build()
            .unwrap();
        let text = spec.to_toml();
        assert!(text.contains("step_us = 12500"), "got:\n{text}");
        assert!(text.contains("autoscale = true"), "got:\n{text}");
        let parsed = DeploymentSpec::from_toml_str(&text).unwrap();
        assert_eq!(parsed, spec);
        assert_eq!(parsed.to_toml(), text, "serialization is a fixed point");
        // Default spec emits none of the optional keys.
        let plain = demo_spec().to_toml();
        assert!(!plain.contains("step_us"), "got:\n{plain}");
        assert!(!plain.contains("autoscale"), "got:\n{plain}");
        assert!(!plain.contains("telemetry"), "got:\n{plain}");
    }

    #[test]
    fn telemetry_section_round_trips() {
        let spec = DeploymentSpec::builder("toml-telemetry")
            .timesteps(8)
            .fc("F1", 16, 4, Resolution::new(4, 8))
            .telemetry_enabled(true)
            .tracing(16)
            .build()
            .unwrap();
        let text = spec.to_toml();
        assert!(text.contains("[telemetry]"), "got:\n{text}");
        assert!(text.contains("trace_sample = 16"), "got:\n{text}");
        let parsed = DeploymentSpec::from_toml_str(&text).unwrap();
        assert_eq!(parsed, spec);
        assert_eq!(parsed.to_toml(), text, "serialization is a fixed point");
        // Keys parse individually too, and stay strict.
        let base = "[network]\npreset = \"serve-demo\"\n";
        let spec = DeploymentSpec::from_toml_str(
            &format!("{base}[telemetry]\nenabled = true\nflight_capacity = 32\n"),
        )
        .unwrap();
        assert!(spec.telemetry.enabled);
        assert!(!spec.telemetry.trace);
        assert_eq!(spec.telemetry.flight_capacity, 32);
        let err = DeploymentSpec::from_toml_str(
            &format!("{base}[telemetry]\nsample = 4\n"),
        )
        .unwrap_err();
        assert!(format!("{err}").contains("telemetry.sample"), "got: {err}");
        let err = DeploymentSpec::from_toml_str(
            &format!("{base}[telemetry]\ntrace_sample = 0\n"),
        )
        .unwrap_err();
        assert!(format!("{err}").contains("trace_sample"), "got: {err}");
    }

    #[test]
    fn precision_section_round_trips() {
        let spec = DeploymentSpec::builder("toml-precision")
            .timesteps(8)
            .fc("F1", 16, 10, Resolution::new(4, 8))
            .adaptive_precision(5.0, 2)
            .build()
            .unwrap();
        let text = spec.to_toml();
        assert!(text.contains("[precision]"), "got:\n{text}");
        assert!(text.contains("max_delta = 2"), "got:\n{text}");
        let parsed = DeploymentSpec::from_toml_str(&text).unwrap();
        assert_eq!(parsed, spec);
        assert_eq!(parsed.to_toml(), text, "serialization is a fixed point");
        // A default spec emits no [precision] section at all, so configs
        // written before the controller existed serialize byte-identically.
        assert!(!demo_spec().to_toml().contains("precision"), "default emits nothing");
        // Keys parse individually and stay strict.
        let base = "[network]\npreset = \"serve-demo\"\n";
        let spec = DeploymentSpec::from_toml_str(&format!(
            "{base}[precision]\nenabled = true\nmax_delta = 4\nqueue_high = 3\n\
             drop_p99_ms = 7.5\nraise_margin = 0.25\nmin_windows = 5\n"
        ))
        .unwrap();
        assert!(spec.precision.enabled);
        assert_eq!(spec.precision.max_delta, 4);
        assert_eq!(spec.precision.queue_high, 3);
        assert!((spec.precision.drop_p99_ms - 7.5).abs() < 1e-12);
        assert!((spec.precision.raise_margin - 0.25).abs() < 1e-12);
        assert_eq!(spec.precision.min_windows, 5);
        let err = DeploymentSpec::from_toml_str(
            &format!("{base}[precision]\ndelta = 4\n"),
        )
        .unwrap_err();
        assert!(format!("{err}").contains("precision.delta"), "got: {err}");
        let err = DeploymentSpec::from_toml_str(
            &format!("{base}[precision]\nmax_delta = 0\n"),
        )
        .unwrap_err();
        assert!(format!("{err}").contains("max_delta"), "got: {err}");
        let err = DeploymentSpec::from_toml_str(
            &format!("{base}[precision]\nmax_delta = 9\n"),
        )
        .unwrap_err();
        assert!(format!("{err}").contains("max_delta"), "got: {err}");
    }

    #[test]
    fn fleet_section_round_trips() {
        let spec = DeploymentSpec::builder("toml-fleet")
            .timesteps(8)
            .fc("F1", 16, 10, Resolution::new(4, 8))
            .fleet(FleetSpec {
                nodes: 4,
                placement: Placement::LayerSharded,
                capacity_sessions: 12,
                vnodes: 32,
                link_pj_per_bit: 25.0,
                max_nodes: 8,
                scale_high_sessions: 6,
            })
            .build()
            .unwrap();
        let text = spec.to_toml();
        assert!(text.contains("[fleet]"), "got:\n{text}");
        assert!(text.contains("placement = \"layer-sharded\""), "got:\n{text}");
        let parsed = DeploymentSpec::from_toml_str(&text).unwrap();
        assert_eq!(parsed, spec);
        assert_eq!(parsed.to_toml(), text, "serialization is a fixed point");
        // A default spec emits no [fleet] section at all, so configs
        // written before the fleet tier existed serialize byte-identically.
        assert!(!demo_spec().to_toml().contains("fleet"), "default emits nothing");
        // Keys parse individually and stay strict.
        let base = "[network]\npreset = \"serve-demo\"\n";
        let spec = DeploymentSpec::from_toml_str(&format!(
            "{base}[fleet]\nnodes = 2\ncapacity_sessions = 5\n"
        ))
        .unwrap();
        assert_eq!(spec.fleet.nodes, 2);
        assert_eq!(spec.fleet.capacity_sessions, 5);
        assert_eq!(spec.fleet.placement, Placement::Replicated);
        let err = DeploymentSpec::from_toml_str(
            &format!("{base}[fleet]\nreplicas = 2\n"),
        )
        .unwrap_err();
        assert!(format!("{err}").contains("fleet.replicas"), "got: {err}");
        let err = DeploymentSpec::from_toml_str(
            &format!("{base}[fleet]\nplacement = \"sharded\"\n"),
        )
        .unwrap_err();
        assert!(format!("{err}").contains("unknown placement"), "got: {err}");
        let err = DeploymentSpec::from_toml_str(
            &format!("{base}[fleet]\nnodes = 0\n"),
        )
        .unwrap_err();
        assert!(format!("{err}").contains("nodes"), "got: {err}");
    }

    #[test]
    fn autoscale_toml_parses_every_knob() {
        let spec = DeploymentSpec::from_toml_str(
            "[network]\npreset = \"serve-demo\"\n[serve]\nworkers = 2\n\
             autoscale = true\nautoscale_min = 2\nautoscale_max = 12\n\
             slo_p99_ms = 7.5\nautoscale_interval_ms = 3\n\
             autoscale_queue_high = 6\nautoscale_hysteresis = 4\n\
             step_us = 5000\nframes_per_window = 8\n",
        )
        .unwrap();
        let a = &spec.serve.autoscale;
        assert!(a.enabled);
        assert_eq!((a.min_workers, a.max_workers), (2, 12));
        assert!((a.slo_p99_ms - 7.5).abs() < 1e-12);
        assert_eq!(a.interval_ms, 3);
        assert_eq!((a.queue_high, a.hysteresis_ticks), (6, 4));
        assert_eq!(spec.serve.step_us, Some(5_000));
        assert_eq!(spec.serve.frames_per_window, Some(8));
    }

    #[test]
    fn invalid_clock_override_rejected_via_toml() {
        let err = DeploymentSpec::from_toml_str(
            "[network]\npreset = \"serve-demo\"\n[serve]\nstep_us = 0\n",
        )
        .unwrap_err();
        assert!(format!("{err}").contains("step_us"), "got: {err}");
        let err = DeploymentSpec::from_toml_str(
            "[network]\npreset = \"serve-demo\"\n[serve]\nworkers = 9\n\
             autoscale = true\nautoscale_max = 4\n",
        )
        .unwrap_err();
        assert!(format!("{err}").contains("autoscale range"), "got: {err}");
    }

    #[test]
    fn zero_workers_rejected_via_toml() {
        let err = DeploymentSpec::from_toml_str(
            "[network]\npreset = \"serve-demo\"\n[serve]\nworkers = 0\n",
        )
        .unwrap_err();
        assert!(format!("{err}").contains("workers"), "got: {err}");
    }
}
