//! The unified deployment API.
//!
//! One typed [`DeploymentSpec`] — network topology, substrate, backend,
//! and serve settings — drives every runtime tier. The spec is
//! constructible three equivalent ways:
//!
//! * **Builder** — [`DeploymentSpec::builder`], a fluent Rust API;
//! * **TOML** — [`DeploymentSpec::from_toml_str`] / [`DeploymentSpec::load`]
//!   (strict parsing: unknown keys are errors) with
//!   [`DeploymentSpec::to_toml`] as the inverse; the shipped presets live
//!   under `configs/` at the repo root;
//! * **Presets** — [`presets::spec`] for the known-good topologies
//!   (`scnn-dvs-gesture`, `serve-demo`).
//!
//! [`DeploymentSpec::deploy`] validates the spec (shape-chained topology,
//! substrate envelope, serve bounds — all with rich errors) and builds the
//! shared state once; the resulting [`Deployment`] then materializes any
//! tier from the same plan:
//!
//! ```text
//!   DeploymentSpec ──deploy()──► Deployment
//!     builder │ TOML │ preset        ├─ .coordinator()  sequential tier
//!                                    ├─ .engine()       batched parallel tier
//!                                    └─ .service()      streaming serve tier
//! ```
//!
//! New networks, resolutions, and serving setups are therefore *data* (a
//! config file or a builder chain), not code changes — the `flexspim`
//! CLI's `run`/`serve`/`map`/`sweep` subcommands all parse their flags
//! into a spec overlay on top of an optional `--config file.toml`.

pub mod handle;
pub mod presets;
pub mod spec;
pub mod toml;
pub mod train;

pub use handle::Deployment;
pub use spec::{
    parse_policy, policy_key, AutoscaleSpec, BackendSpec, DeploymentBuilder, DeploymentSpec,
    FleetSpec, LayerDef, NetworkSpec, Placement, PrecisionSpec, ServeSpec, SubstrateSpec,
    TelemetrySpec,
};
pub use train::{SimulateConfig, TrainConfig, TrainSpec};
