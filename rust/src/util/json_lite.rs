//! Minimal JSON parser (no serde offline): enough to validate the
//! telemetry exporters' output — Chrome `trace_event` files, the
//! deterministic [`TelemetrySnapshot`](crate::telemetry::TelemetrySnapshot)
//! rendering, and `BENCH_JSON` lines — from tests without external
//! crates.
//!
//! Full JSON value grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null); parse errors carry a byte offset. Not a
//! serializer and not performance-tuned: the writers in this crate
//! emit JSON by hand, this is the *reader* that keeps them honest.

use crate::Result;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (parsed as f64).
    Num(f64),
    /// String (escapes resolved).
    Str(String),
    /// Array.
    Arr(Vec<Value>),
    /// Object, in source order.
    Obj(Vec<(String, Value)>),
}

impl Value {
    /// Member of an object by key (first match), if this is an object.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(members) => members.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The string contents, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The number, if this is a number.
    pub fn as_num(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
}

/// Parse one complete JSON document (trailing whitespace allowed,
/// trailing garbage is an error).
pub fn parse(src: &str) -> Result<Value> {
    let mut p = Parser { bytes: src.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    anyhow::ensure!(
        p.pos == p.bytes.len(),
        "trailing garbage at byte {} of JSON document",
        p.pos
    );
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        anyhow::ensure!(
            self.peek() == Some(b),
            "expected '{}' at byte {}",
            b as char,
            self.pos
        );
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}", other.map(|b| b as char), self.pos),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value> {
        anyhow::ensure!(
            self.bytes[self.pos..].starts_with(word.as_bytes()),
            "invalid literal at byte {}",
            self.pos
        );
        self.pos += word.len();
        Ok(v)
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut members = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(members));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            members.push((key, self.value()?));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(members));
                }
                other => anyhow::bail!(
                    "expected ',' or '}}' at byte {}, got {:?}",
                    self.pos,
                    other.map(|b| b as char)
                ),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                other => anyhow::bail!(
                    "expected ',' or ']' at byte {}, got {:?}",
                    self.pos,
                    other.map(|b| b as char)
                ),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let Some(b) = self.peek() else {
                anyhow::bail!("unterminated string at byte {}", self.pos);
            };
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(esc) = self.peek() else {
                        anyhow::bail!("unterminated escape at byte {}", self.pos);
                    };
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            anyhow::ensure!(
                                self.pos + 4 <= self.bytes.len(),
                                "truncated \\u escape at byte {}",
                                self.pos
                            );
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| anyhow::anyhow!("bad \\u escape at byte {}", self.pos))?;
                            self.pos += 4;
                            // Surrogate pairs are out of scope for the
                            // crate's own output; map them to U+FFFD.
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        other => anyhow::bail!(
                            "unknown escape '\\{}' at byte {}",
                            other as char,
                            self.pos
                        ),
                    }
                }
                _ => {
                    // Re-borrow the raw byte run to keep UTF-8 intact.
                    let start = self.pos - 1;
                    while self
                        .peek()
                        .is_some_and(|c| c != b'"' && c != b'\\')
                    {
                        self.pos += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let n: f64 = text
            .parse()
            .map_err(|_| anyhow::anyhow!("invalid number '{text}' at byte {start}"))?;
        Ok(Value::Num(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_nested_document() {
        let v = parse(
            r#"{"a": [1, -2.5, true, null], "b": {"c": "x\"y"}, "n": 1e3}"#,
        )
        .unwrap();
        assert_eq!(v.get("n").and_then(Value::as_num), Some(1000.0));
        let arr = v.get("a").and_then(Value::as_arr).unwrap();
        assert_eq!(arr.len(), 4);
        assert_eq!(arr[1], Value::Num(-2.5));
        assert_eq!(arr[2], Value::Bool(true));
        assert_eq!(arr[3], Value::Null);
        assert_eq!(
            v.get("b").and_then(|b| b.get("c")).and_then(Value::as_str),
            Some("x\"y")
        );
    }

    #[test]
    fn empty_containers_and_unicode_escape() {
        assert_eq!(parse("{}").unwrap(), Value::Obj(vec![]));
        assert_eq!(parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(parse(r#""A""#).unwrap(), Value::Str("A".to_string()));
    }

    #[test]
    fn rejects_malformed_input() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("12 34").is_err(), "trailing garbage");
        assert!(parse("\"unterminated").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn round_trips_bench_json_line() {
        let line = r#"{"bench":"serve_saturation","workers":1,"p99_ms":3.25,"shed_rate":0}"#;
        let v = parse(line).unwrap();
        assert_eq!(v.get("bench").and_then(Value::as_str), Some("serve_saturation"));
        assert_eq!(v.get("workers").and_then(Value::as_num), Some(1.0));
        assert_eq!(v.get("p99_ms").and_then(Value::as_num), Some(3.25));
    }
}
