//! Micro-benchmark harness (criterion is not vendored offline).
//!
//! `cargo bench` binaries declare `harness = false` and call [`Bench::run`]
//! / [`Bench::report`]. The harness does warm-up, adaptive iteration-count
//! selection to hit a target measurement time, and reports median / mean /
//! p95 per iteration so bench output is stable enough to compare before vs
//! after optimization (EXPERIMENTS.md §Perf).

use std::time::{Duration, Instant};

use super::stats::{median, percentile};

/// One benchmark measurement result.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Name of the benchmark case.
    pub name: String,
    /// Per-iteration wall time samples (seconds).
    pub samples: Vec<f64>,
    /// Iterations per sample batch.
    pub iters_per_sample: u64,
}

impl Measurement {
    /// Median seconds per iteration.
    pub fn median_s(&self) -> f64 {
        median(&self.samples)
    }

    /// Mean seconds per iteration.
    pub fn mean_s(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len().max(1) as f64
    }

    /// p95 seconds per iteration.
    pub fn p95_s(&self) -> f64 {
        percentile(&self.samples, 95.0)
    }

    /// Render a single aligned report line.
    pub fn line(&self) -> String {
        format!(
            "{:<44} median {:>12}  mean {:>12}  p95 {:>12}  ({} samples x {} iters)",
            self.name,
            fmt_time(self.median_s()),
            fmt_time(self.mean_s()),
            fmt_time(self.p95_s()),
            self.samples.len(),
            self.iters_per_sample,
        )
    }
}

/// Format seconds with an appropriate unit.
pub fn fmt_time(s: f64) -> String {
    if !s.is_finite() {
        return "n/a".into();
    }
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Benchmark runner configuration.
#[derive(Debug, Clone)]
pub struct Bench {
    /// Warm-up duration before measuring.
    pub warmup: Duration,
    /// Total measurement budget per case.
    pub measure: Duration,
    /// Number of sample batches to split the budget into.
    pub samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(1200),
            samples: 20,
        }
    }
}

impl Bench {
    /// Quick harness for cheap functions in CI-like environments.
    pub fn quick() -> Self {
        Bench {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(300),
            samples: 10,
        }
    }

    /// Measure `f`, returning per-iteration timing statistics. The closure's
    /// return value is consumed with `std::hint::black_box` to prevent the
    /// optimizer from deleting the work.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Measurement {
        // Warm-up and calibration: find iters/sample so a batch lasts
        // measure/samples.
        let mut iters = 1u64;
        let t0 = Instant::now();
        loop {
            let start = Instant::now();
            for _ in 0..iters {
                std::hint::black_box(f());
            }
            let dt = start.elapsed();
            if t0.elapsed() >= self.warmup && dt >= Duration::from_micros(50) {
                let per_iter = dt.as_secs_f64() / iters as f64;
                let target = self.measure.as_secs_f64() / self.samples as f64;
                iters = ((target / per_iter).ceil() as u64).max(1);
                break;
            }
            iters = iters.saturating_mul(2).min(1 << 30);
        }

        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let start = Instant::now();
            for _ in 0..iters {
                std::hint::black_box(f());
            }
            samples.push(start.elapsed().as_secs_f64() / iters as f64);
        }
        Measurement {
            name: name.to_string(),
            samples,
            iters_per_sample: iters,
        }
    }

    /// Run and print in one step; returns the measurement for programmatic use.
    pub fn report<T>(&self, name: &str, f: impl FnMut() -> T) -> Measurement {
        let m = self.run(name, f);
        crate::log_info!("{}", m.line());
        m
    }
}

/// Print a section header for a bench binary.
pub fn section(title: &str) {
    crate::log_info!("\n=== {title} ===");
}

/// True when `BENCH_QUICK` is set (CI smoke runs): benches shrink their
/// workloads to finish in seconds while still exercising every code path.
pub fn quick_mode() -> bool {
    std::env::var("BENCH_QUICK").map(|v| !v.is_empty() && v != "0").unwrap_or(false)
}

/// Format one machine-readable JSON line for a bench result, prefixed
/// `BENCH_JSON `, so the bench trajectory (`BENCH_*.json`) can be scraped
/// and tracked across PRs. Integral values print without a fraction,
/// non-finite values as `null` (JSON has no NaN/inf), everything else
/// with six decimals.
pub fn json_line(bench: &str, fields: &[(&str, f64)]) -> String {
    let mut body = format!("{{\"bench\":\"{bench}\"");
    for (k, v) in fields {
        if !v.is_finite() {
            body.push_str(&format!(",\"{k}\":null"));
        } else if v.fract() == 0.0 && v.abs() < 1e15 {
            body.push_str(&format!(",\"{k}\":{}", *v as i64));
        } else {
            body.push_str(&format!(",\"{k}\":{v:.6}"));
        }
    }
    body.push('}');
    format!("BENCH_JSON {body}")
}

/// Print a [`json_line`]. Emitted at Info level (bare stdout), so the
/// `grep '^BENCH_JSON '` capture contract in `scripts/capture_bench.sh`
/// holds as long as the log level admits Info.
pub fn emit_json(bench: &str, fields: &[(&str, f64)]) {
    crate::log_info!("{}", json_line(bench, fields));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_cheap_fn() {
        let b = Bench::quick();
        let m = b.run("noop-ish", || std::hint::black_box(3u64).wrapping_mul(7));
        assert_eq!(m.samples.len(), 10);
        assert!(m.median_s() > 0.0);
        assert!(m.median_s() < 1e-3, "cheap op should be far below 1ms");
    }

    #[test]
    fn ordering_detects_slower_fn() {
        // Large work gap + black_box'd loop so the comparison holds even
        // under heavy parallel-test CPU load.
        let b = Bench::quick();
        let fast = b.run("fast", || std::hint::black_box(1u64).wrapping_mul(3));
        let slow = b.run("slow", || {
            let mut acc = 0u64;
            for i in 0..100_000u64 {
                acc = acc.wrapping_add(std::hint::black_box(i));
            }
            acc
        });
        assert!(
            slow.median_s() > 3.0 * fast.median_s(),
            "slow {} vs fast {}",
            slow.median_s(),
            fast.median_s()
        );
    }

    #[test]
    fn fmt_time_units() {
        assert_eq!(fmt_time(2.0), "2.000 s");
        assert_eq!(fmt_time(2e-3), "2.000 ms");
        assert_eq!(fmt_time(2e-6), "2.000 µs");
        assert_eq!(fmt_time(2e-9), "2.0 ns");
    }

    #[test]
    fn json_line_shape() {
        let line = json_line("engine_throughput", &[("workers", 4.0), ("sps", 12.5)]);
        assert_eq!(
            line,
            "BENCH_JSON {\"bench\":\"engine_throughput\",\"workers\":4,\"sps\":12.500000}"
        );
    }

    #[test]
    fn json_line_non_finite_values_stay_valid_json() {
        let line = json_line("x", &[("a", f64::NAN), ("b", f64::INFINITY)]);
        assert_eq!(line, "BENCH_JSON {\"bench\":\"x\",\"a\":null,\"b\":null}");
    }
}
