//! Minimal command-line argument parser (clap is not vendored offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments, with typed getters and an auto-generated usage string.

use std::collections::BTreeMap;

/// Parsed arguments: flags, key/value options, and positionals in order.
#[derive(Debug, Default, Clone)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

/// Declared option for usage/help rendering and value-vs-flag disambiguation.
#[derive(Debug, Clone)]
pub struct Spec {
    /// Option name without leading dashes, e.g. `"seed"`.
    pub name: &'static str,
    /// True if the option takes a value (`--seed 42`); false for bare flags.
    pub takes_value: bool,
    /// One-line help string.
    pub help: &'static str,
}

impl Args {
    /// Parse `argv` (excluding program name) against the declared `specs`.
    /// Unknown `--options` are an error so typos fail fast.
    pub fn parse(argv: &[String], specs: &[Spec]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| format!("unknown option --{name}"))?;
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{name} requires a value"))?
                        }
                    };
                    out.opts.insert(name, val);
                } else {
                    if inline_val.is_some() {
                        return Err(format!("--{name} does not take a value"));
                    }
                    out.flags.push(name);
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    /// Raw string value of `--name`, if given.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    /// Value of `--name` parsed as `T`, or `default` when absent.
    pub fn get_or<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.get(name) {
            Some(v) => v.parse().unwrap_or(default),
            None => default,
        }
    }

    /// Typed value of `--name` with a parse error surfaced.
    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, String>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|e| format!("--{name}={v}: {e}")),
        }
    }

    /// Whether a bare `--name` flag was given.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Positional arguments, in order.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// Render a usage block from specs.
pub fn usage(cmd: &str, specs: &[Spec]) -> String {
    let mut s = format!("usage: {cmd} [options]\n");
    for spec in specs {
        let head = if spec.takes_value {
            format!("  --{} <v>", spec.name)
        } else {
            format!("  --{}", spec.name)
        };
        s.push_str(&format!("{head:<26}{}\n", spec.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<Spec> {
        vec![
            Spec { name: "seed", takes_value: true, help: "rng seed" },
            Spec { name: "verbose", takes_value: false, help: "chatty" },
            Spec { name: "out", takes_value: true, help: "output path" },
        ]
    }

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_kv_flag_positional() {
        let a = Args::parse(&sv(&["run", "--seed", "42", "--verbose", "x.txt"]), &specs())
            .unwrap();
        assert_eq!(a.get("seed"), Some("42"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["run".to_string(), "x.txt".to_string()]);
        assert_eq!(a.get_or::<u64>("seed", 0), 42);
    }

    #[test]
    fn equals_form() {
        let a = Args::parse(&sv(&["--seed=7"]), &specs()).unwrap();
        assert_eq!(a.get_or::<u64>("seed", 0), 7);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(Args::parse(&sv(&["--nope"]), &specs()).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(Args::parse(&sv(&["--seed"]), &specs()).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(Args::parse(&sv(&["--verbose=1"]), &specs()).is_err());
    }

    #[test]
    fn typed_parse_error_reported() {
        let a = Args::parse(&sv(&["--seed", "abc"]), &specs()).unwrap();
        assert!(a.get_parsed::<u64>("seed").is_err());
        assert_eq!(a.get_or::<u64>("seed", 5), 5, "fallback on bad parse");
    }

    #[test]
    fn usage_mentions_all() {
        let u = usage("flexspim", &specs());
        assert!(u.contains("--seed") && u.contains("--verbose") && u.contains("--out"));
    }
}
