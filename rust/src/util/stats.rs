//! Streaming/aggregate statistics used by benches and metric reporting.

/// Online mean/variance (Welford) plus min/max tracking.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    /// Empty summary.
    pub fn new() -> Self {
        Summary {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Absorb one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Arithmetic mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Sample standard deviation (0 for < 2 observations).
    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    /// Minimum observation (NaN when empty).
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.min
        }
    }

    /// Maximum observation (NaN when empty).
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.max
        }
    }
}

/// Percentile of a sample via linear interpolation (sorts a copy).
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

/// Percentile of an already-ascending sample via linear interpolation —
/// O(1), no copy. Callers that keep their samples sorted (e.g.
/// [`crate::coordinator::LatencyStats`]) use this to answer p50/p95/p99
/// without re-sorting per query.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p));
    if sorted.is_empty() {
        return f64::NAN;
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Median convenience wrapper.
pub fn median(samples: &[f64]) -> f64 {
    percentile(samples, 50.0)
}

/// Relative difference `|a-b| / max(|a|,|b|)`; 0 when both are 0.
pub fn rel_diff(a: f64, b: f64) -> f64 {
    let m = a.abs().max(b.abs());
    if m == 0.0 {
        0.0
    } else {
        (a - b).abs() / m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // Sample std of this classic dataset is ~2.138.
        assert!((s.std() - 2.1380899).abs() < 1e-6);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn empty_summary() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.std(), 0.0);
        assert!(s.min().is_nan());
    }

    #[test]
    fn percentiles() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((median(&v) - 50.5).abs() < 1e-9);
        assert!((percentile(&v, 0.0) - 1.0).abs() < 1e-9);
        assert!((percentile(&v, 100.0) - 100.0).abs() < 1e-9);
        assert!((percentile(&v, 95.0) - 95.05).abs() < 1e-9);
    }

    #[test]
    fn percentile_sorted_matches_percentile() {
        let v: Vec<f64> = (1..=100).rev().map(|i| i as f64).collect();
        let mut sorted = v.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for p in [0.0, 12.5, 50.0, 95.0, 99.0, 100.0] {
            assert!((percentile(&v, p) - percentile_sorted(&sorted, p)).abs() < 1e-12);
        }
        assert!(percentile_sorted(&[], 50.0).is_nan());
    }

    #[test]
    fn rel_diff_cases() {
        assert_eq!(rel_diff(0.0, 0.0), 0.0);
        assert!((rel_diff(10.0, 9.0) - 0.1).abs() < 1e-12);
        assert!((rel_diff(-10.0, 10.0) - 2.0).abs() < 1e-12);
    }
}
