//! Streaming/aggregate statistics used by benches and metric reporting.

/// Online mean/variance (Welford) plus min/max tracking.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    /// Empty summary.
    pub fn new() -> Self {
        Summary {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Absorb one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Arithmetic mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Sample standard deviation (0 for < 2 observations).
    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    /// Minimum observation (NaN when empty).
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.min
        }
    }

    /// Maximum observation (NaN when empty).
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.max
        }
    }
}

/// Percentile of a sample via linear interpolation (sorts a copy).
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p));
    if samples.is_empty() {
        return f64::NAN;
    }
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Median convenience wrapper.
pub fn median(samples: &[f64]) -> f64 {
    percentile(samples, 50.0)
}

/// Relative difference `|a-b| / max(|a|,|b|)`; 0 when both are 0.
pub fn rel_diff(a: f64, b: f64) -> f64 {
    let m = a.abs().max(b.abs());
    if m == 0.0 {
        0.0
    } else {
        (a - b).abs() / m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // Sample std of this classic dataset is ~2.138.
        assert!((s.std() - 2.1380899).abs() < 1e-6);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn empty_summary() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.std(), 0.0);
        assert!(s.min().is_nan());
    }

    #[test]
    fn percentiles() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((median(&v) - 50.5).abs() < 1e-9);
        assert!((percentile(&v, 0.0) - 1.0).abs() < 1e-9);
        assert!((percentile(&v, 100.0) - 100.0).abs() < 1e-9);
        assert!((percentile(&v, 95.0) - 95.05).abs() < 1e-9);
    }

    #[test]
    fn rel_diff_cases() {
        assert_eq!(rel_diff(0.0, 0.0), 0.0);
        assert!((rel_diff(10.0, 9.0) - 0.1).abs() < 1e-12);
        assert!((rel_diff(-10.0, 10.0) - 2.0).abs() < 1e-12);
    }
}
