//! Minimal property-based testing harness (proptest is not vendored
//! offline).
//!
//! A property is a closure over a [`Rng`]-driven generated case. The runner
//! executes `cases` random cases from a fixed seed; on failure it attempts a
//! bounded shrink loop by re-generating with "smaller" size hints and
//! reports the failing seed so the case can be replayed deterministically.

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    /// Number of random cases.
    pub cases: u32,
    /// Base seed; each case `i` runs with seed `base_seed + i`.
    pub base_seed: u64,
    /// Maximum size hint passed to generators (scales ranges/lengths).
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cases: 256,
            base_seed: 0xF1E2_D3C4_B5A6_9788,
            max_size: 64,
        }
    }
}

/// Context handed to each property case: a seeded RNG plus a size hint that
/// grows with the case index (small cases first, mimicking proptest).
pub struct Case<'a> {
    /// Seeded random generator for this case.
    pub rng: &'a mut Rng,
    /// Size hint in `[1, max_size]`.
    pub size: usize,
    /// Case index (for diagnostics).
    pub index: u32,
}

/// Run `prop` on `cfg.cases` generated cases. `prop` returns
/// `Err(description)` to fail. Panics with a replayable seed on failure.
pub fn check<F>(name: &str, cfg: &Config, mut prop: F)
where
    F: FnMut(&mut Case) -> Result<(), String>,
{
    for i in 0..cfg.cases {
        let seed = cfg.base_seed.wrapping_add(i as u64);
        // Ramp size from 1 to max_size over the first half of cases, then
        // stay at max: small counterexamples surface first.
        let half = (cfg.cases / 2).max(1);
        let size = if i < half {
            1 + (i as usize * (cfg.max_size - 1)) / half as usize
        } else {
            cfg.max_size
        };
        let mut rng = Rng::new(seed);
        let mut case = Case { rng: &mut rng, size, index: i };
        if let Err(msg) = prop(&mut case) {
            // Shrink attempt: replay the same seed with smaller sizes and
            // report the smallest size that still fails.
            let mut smallest = (size, msg.clone());
            let mut s = size;
            while s > 1 {
                s /= 2;
                let mut rng = Rng::new(seed);
                let mut case = Case { rng: &mut rng, size: s, index: i };
                if let Err(m) = prop(&mut case) {
                    smallest = (s, m);
                }
            }
            panic!(
                "property '{name}' failed at case {i} (seed {seed:#x}, size {}): {}",
                smallest.0, smallest.1
            );
        }
    }
}

/// Assert-eq helper producing a property error instead of panicking, so the
/// shrink loop can continue.
pub fn prop_eq<T: PartialEq + std::fmt::Debug>(a: T, b: T, ctx: &str) -> Result<(), String> {
    if a == b {
        Ok(())
    } else {
        Err(format!("{ctx}: {a:?} != {b:?}"))
    }
}

/// Assert a boolean condition as a property result.
pub fn prop_assert(cond: bool, ctx: &str) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(ctx.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0u32;
        check("add-commutes", &Config { cases: 50, ..Default::default() }, |c| {
            count += 1;
            let a = c.rng.range_i64(-1000, 1000);
            let b = c.rng.range_i64(-1000, 1000);
            prop_eq(a + b, b + a, "commutativity")
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check("always-fails", &Config { cases: 5, ..Default::default() }, |_c| {
            Err("nope".to_string())
        });
    }

    #[test]
    fn size_ramps_up() {
        let mut sizes = Vec::new();
        check("observe-size", &Config { cases: 20, max_size: 64, ..Default::default() }, |c| {
            sizes.push(c.size);
            Ok(())
        });
        assert!(sizes[0] < *sizes.last().unwrap());
        assert_eq!(*sizes.last().unwrap(), 64);
    }

    #[test]
    fn deterministic_cases() {
        let mut first: Vec<i64> = Vec::new();
        check("record", &Config { cases: 10, ..Default::default() }, |c| {
            first.push(c.rng.range_i64(0, 1 << 30));
            Ok(())
        });
        let mut second: Vec<i64> = Vec::new();
        check("record", &Config { cases: 10, ..Default::default() }, |c| {
            second.push(c.rng.range_i64(0, 1 << 30));
            Ok(())
        });
        assert_eq!(first, second);
    }
}
