//! Deterministic pseudo-random number generation.
//!
//! `rand` is not vendored in the offline build environment, so this module
//! implements SplitMix64 (seeding) and xoshiro256** (bulk generation) —
//! both public-domain algorithms with well-known reference outputs that the
//! unit tests pin down. Everything in the crate that needs randomness
//! (synthetic DVS streams, property tests, workload generators) goes through
//! [`Rng`], so every run is reproducible from a single `u64` seed.

/// SplitMix64 step; used to expand a single seed into a full xoshiro state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG. Deterministic, fast, and good enough statistically for
/// simulation workloads (not cryptographic).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Next 32-bit output (upper bits of the 64-bit stream).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform float in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform float in `[0, 1)` (f32).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)`; `bound` must be non-zero.
    /// Uses Lemire's multiply-shift rejection for unbiased results.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "Rng::below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= (bound.wrapping_neg() % bound) {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi as i128 - lo as i128 + 1) as u64;
        lo.wrapping_add(self.below(span) as i64)
    }

    /// Uniform usize in `[lo, hi]`.
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_i64(lo as i64, hi as i64) as usize
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple & adequate).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Poisson draw (Knuth for small lambda, normal approximation above 30).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 30.0 {
            let v = lambda + lambda.sqrt() * self.normal();
            return v.max(0.0).round() as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len() as u64) as usize]
    }

    /// Derive an independent child generator (for parallel streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 from the public-domain C code.
        let mut s = 1234567u64;
        let a = splitmix64(&mut s);
        let b = splitmix64(&mut s);
        assert_ne!(a, b);
        // Determinism: same seed, same stream.
        let mut s2 = 1234567u64;
        assert_eq!(a, splitmix64(&mut s2));
        assert_eq!(b, splitmix64(&mut s2));
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut r = Rng::new(99);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean} too far from 0.5");
    }

    #[test]
    fn range_inclusive_bounds() {
        let mut r = Rng::new(5);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..2000 {
            let v = r.range_i64(-3, 3);
            assert!((-3..=3).contains(&v));
            lo_seen |= v == -3;
            hi_seen |= v == 3;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn poisson_mean_matches_lambda() {
        let mut r = Rng::new(11);
        for &lambda in &[0.5, 4.0, 60.0] {
            let n = 5000;
            let mean: f64 = (0..n).map(|_| r.poisson(lambda) as f64).sum::<f64>() / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.max(1.0) * 0.1,
                "lambda {lambda}: mean {mean}"
            );
        }
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "astronomically unlikely");
    }

    #[test]
    fn fork_streams_diverge() {
        let mut r = Rng::new(1);
        let mut c1 = r.fork();
        let mut c2 = r.fork();
        let s1: Vec<u64> = (0..8).map(|_| c1.next_u64()).collect();
        let s2: Vec<u64> = (0..8).map(|_| c2.next_u64()).collect();
        assert_ne!(s1, s2);
    }
}
