//! Small self-contained utilities.
//!
//! The build environment is offline and only ships the crates vendored with
//! the `xla` example, so the usual ecosystem helpers (`rand`, `clap`,
//! `criterion`, `proptest`) are hand-rolled here. Each module is tested on
//! its own so the rest of the crate can rely on them.

pub mod bench;
pub mod cli;
pub mod json_lite;
pub mod proptest_lite;
pub mod rng;
pub mod stats;

/// Format a float with SI-style engineering prefixes (e.g. `1.23 M`).
pub fn si(value: f64) -> String {
    let (v, p) = si_parts(value);
    if p.is_empty() {
        format!("{v:.3}")
    } else {
        format!("{v:.3} {p}")
    }
}

/// Split a value into a mantissa and SI prefix.
pub fn si_parts(value: f64) -> (f64, &'static str) {
    let a = value.abs();
    if a == 0.0 || !a.is_finite() {
        return (value, "");
    }
    const UP: [&str; 4] = ["k", "M", "G", "T"];
    const DOWN: [&str; 4] = ["m", "µ", "n", "p"];
    if a >= 1.0 && a < 1000.0 {
        return (value, "");
    }
    if a >= 1000.0 {
        let mut v = value;
        for p in UP {
            v /= 1000.0;
            if v.abs() < 1000.0 {
                return (v, p);
            }
        }
        return (v, "T");
    }
    let mut v = value;
    for p in DOWN {
        v *= 1000.0;
        if v.abs() >= 1.0 {
            return (v, p);
        }
    }
    (v, "p")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn si_ranges() {
        assert_eq!(si(0.0), "0.000");
        assert_eq!(si(12.5), "12.500");
        assert_eq!(si(1_500.0), "1.500 k");
        assert_eq!(si(2.5e9), "2.500 G");
        assert_eq!(si(5.7e-12), "5.700 p");
        assert!(si(44.5e-15).ends_with(" p") && si(44.5e-15).starts_with("0.04"));
        assert_eq!(si(-3.2e6), "-3.200 M");
    }
}
