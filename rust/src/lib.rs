//! # FlexSpIM
//!
//! Full-system reproduction of *"An Event-Based Digital Compute-In-Memory
//! Accelerator with Flexible Operand Resolution and Layer-Wise Weight/Output
//! Stationarity"* (Chauvaux et al., cs.AR 2024).
//!
//! The fabricated 40-nm chip is replaced by a bit-accurate simulator plus an
//! energy model calibrated to the paper's silicon measurements. The stack is
//! three layers:
//!
//! * **L1** — Pallas kernels (build-time Python) implementing the quantized
//!   integrate-and-fire hot loop, checked against a pure-jnp oracle.
//! * **L2** — a JAX spiking-CNN model lowered AOT to HLO text artifacts.
//! * **L3** — this crate: the coordinator, the bit-accurate CIM macro
//!   simulator, the hybrid-stationary dataflow mapper, the calibrated energy
//!   model, the synthetic DVS event substrate, and the PJRT runtime that
//!   executes the AOT artifacts on the request path (Python never runs at
//!   inference time).
//!
//! Entry point: [`deploy::DeploymentSpec`] — one typed spec (built
//! fluently or loaded from TOML) that materializes every tier via
//! [`deploy::Deployment`]: the sequential [`coordinator::Coordinator`],
//! the batched parallel [`coordinator::Engine`], and the streaming
//! [`serve::StreamingService`]. Lower-level pieces remain public:
//! [`cim::CimMacro`] for the macro simulator, [`dataflow::Mapper`] for the
//! HS mapping search, and [`figures`] for the paper-figure drivers.
//! Observability for all tiers lives in [`telemetry`] (leveled logging,
//! a metrics registry with Prometheus/JSON exporters, Chrome-trace
//! spans, and a per-service flight recorder).

pub mod cim;
pub mod config;
pub mod coordinator;
pub mod dataflow;
pub mod deploy;
pub mod energy;
pub mod events;
pub mod figures;
pub mod fleet;
pub mod runtime;
pub mod serve;
pub mod snn;
pub mod telemetry;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
