//! FlexSpIM command-line interface.
//!
//! ```text
//! flexspim reproduce <fig4|fig6|fig7a|fig7cd|table1|all>
//! flexspim run       [--config F] [--samples N] [--macros M] [--policy P]
//!                    [--seed S] [--backend B] [--vdd V] [--full]
//! flexspim serve     [--config F] [--sessions N] [--workers W] [--jitter-us J]
//!                    [--budget-kb B] [--macros M] [--policy P] [--seed S] [--full]
//!                    [--deterministic] [--exit-margin X]
//!                    [--step-us U] [--frames-per-window K]
//!                    [--autoscale] [--autoscale-max W] [--slo-p99-ms X]
//!                    [--precision] [--precision-max-delta D]
//!                    [--precision-p99-ms X] [--precision-margin M]
//! flexspim fleet     [--config F] [--sessions N] [--nodes N] [--max-nodes N]
//!                    [--placement replicated|layer-sharded] [--rate R]
//!                    [--time-scale X] [--seed S] [--jitter-us J]
//! flexspim train     [--config F] [--steps N] [--lr X] [--seed S] [--out PATH]
//! flexspim map       [--config F] [--macros M]
//! flexspim simulate  [--config F] [--wbits W] [--pbits P] [--nc C]
//!                    [--neurons N] [--fanin F]
//! flexspim sweep     [--config F] [--samples N] [--seed S] [--macros M]
//! ```
//!
//! `run`, `serve`, `fleet`, `map`, and `sweep` all build one
//! [`flexspim::deploy::DeploymentSpec`]: start from `--config file.toml`
//! (or the subcommand's default preset), overlay the CLI flags, then
//! materialize the tier they need. `train` and `simulate` follow the same
//! pattern over a [`flexspim::deploy::TrainSpec`]
//! (`configs/train_demo.toml`). Defaults use the pure-Rust native
//! backend and run everywhere; `--backend pjrt` (or a config's
//! `backend.kind = "pjrt"`) needs the AOT artifacts (`make artifacts`),
//! as does `train`.
//!
//! Observability: `--verbosity` (or `FLEXSPIM_LOG`) sets the log level;
//! `--telemetry` enables the metrics registry and flight recorder,
//! `--dump-telemetry` prints them after a serve run, and `--trace PATH`
//! captures a Chrome `trace_event` JSON of the hot seams.

use std::path::Path;

use anyhow::{anyhow, bail, Result};
use flexspim::cim::{CimMacro, MacroConfig};
use flexspim::deploy::{parse_policy, presets, BackendSpec, DeploymentSpec};
use flexspim::energy::MacroEnergyModel;
use flexspim::events::GestureGenerator;
use flexspim::figures::{fig4, fig6, fig7, table1};
use flexspim::runtime::{artifacts_dir, Runtime, TrainRunner};
use flexspim::snn::network::scnn_dvs_gesture;
use flexspim::telemetry::log::{self as tlog, Level};
use flexspim::util::cli::{usage, Args, Spec};
use flexspim::util::rng::Rng;
use flexspim::{log_error, log_info};

fn specs() -> Vec<Spec> {
    vec![
        Spec { name: "config", takes_value: true, help: "TOML deployment spec (configs/*.toml)" },
        Spec { name: "samples", takes_value: true, help: "samples per class (default 2)" },
        Spec { name: "macros", takes_value: true, help: "number of CIM macros" },
        Spec { name: "policy", takes_value: true, help: "ws-only|os-only|hs-min|hs-max|hs-opt" },
        Spec { name: "seed", takes_value: true, help: "rng / weight-stream seed (default 42)" },
        Spec {
            name: "backend",
            takes_value: true,
            help: "native|native-dense|pjrt (overrides the spec)",
        },
        Spec { name: "vdd", takes_value: true, help: "supply voltage, 0.9-1.1 V" },
        Spec { name: "steps", takes_value: true, help: "training steps (default 100)" },
        Spec { name: "lr", takes_value: true, help: "learning rate (default 0.05)" },
        Spec { name: "out", takes_value: true, help: "output path for trained weights" },
        Spec { name: "wbits", takes_value: true, help: "weight bits (simulate)" },
        Spec { name: "pbits", takes_value: true, help: "membrane bits (simulate)" },
        Spec { name: "nc", takes_value: true, help: "operand columns N_C (simulate)" },
        Spec { name: "neurons", takes_value: true, help: "parallel neurons (simulate)" },
        Spec { name: "fanin", takes_value: true, help: "synapses per neuron (simulate)" },
        Spec { name: "sessions", takes_value: true, help: "streaming sessions (serve, default 16)" },
        Spec { name: "workers", takes_value: true, help: "serve/engine worker threads" },
        Spec { name: "jitter-us", takes_value: true, help: "arrival jitter in us (serve)" },
        Spec { name: "budget-kb", takes_value: true, help: "vmem budget kB (serve, 0 = chip)" },
        Spec {
            name: "deterministic",
            takes_value: false,
            help: "serve: dispatch in admission order (reproducible residency)",
        },
        Spec {
            name: "exit-margin",
            takes_value: true,
            help: "serve: early-exit confidence margin (0 = off)",
        },
        Spec { name: "step-us", takes_value: true, help: "serve: session timestep in us" },
        Spec {
            name: "frames-per-window",
            takes_value: true,
            help: "serve: timesteps per micro-window",
        },
        Spec { name: "autoscale", takes_value: false, help: "serve: enable the SLO autoscaler" },
        Spec {
            name: "autoscale-max",
            takes_value: true,
            help: "serve: autoscaler pool ceiling (implies --autoscale)",
        },
        Spec {
            name: "slo-p99-ms",
            takes_value: true,
            help: "serve: autoscaler p99 latency objective in ms (implies --autoscale)",
        },
        Spec {
            name: "precision",
            takes_value: false,
            help: "serve: enable the per-session precision controller",
        },
        Spec {
            name: "precision-max-delta",
            takes_value: true,
            help: "serve: deepest resolution tier, 1..=7 (implies --precision)",
        },
        Spec {
            name: "precision-p99-ms",
            takes_value: true,
            help: "serve: p99 above this drops a resolution tier (implies --precision)",
        },
        Spec {
            name: "precision-margin",
            takes_value: true,
            help: "serve: margin below this raises a resolution tier (implies --precision)",
        },
        Spec { name: "nodes", takes_value: true, help: "fleet: replica nodes at boot" },
        Spec {
            name: "max-nodes",
            takes_value: true,
            help: "fleet: autoscale-join ceiling (0 = no autoscale)",
        },
        Spec {
            name: "placement",
            takes_value: true,
            help: "fleet: replicated|layer-sharded weight placement",
        },
        Spec {
            name: "rate",
            takes_value: true,
            help: "fleet: offered session arrivals per second (default 200)",
        },
        Spec {
            name: "time-scale",
            takes_value: true,
            help: "fleet: intra-session replay speedup (default 10)",
        },
        Spec {
            name: "verbosity",
            takes_value: true,
            help: "log level: error|warn|info|debug|trace (or FLEXSPIM_LOG)",
        },
        Spec {
            name: "telemetry",
            takes_value: false,
            help: "enable the metrics registry + flight recorder",
        },
        Spec {
            name: "dump-telemetry",
            takes_value: false,
            help: "serve: print the flight recorder and exporters after the run",
        },
        Spec {
            name: "trace",
            takes_value: true,
            help: "write a Chrome trace_event JSON of the run to PATH",
        },
        Spec {
            name: "trace-sample",
            takes_value: true,
            help: "record 1 in N trace spans (default 64, implies --trace capture)",
        },
        Spec { name: "full", takes_value: false, help: "use the full paper SCNN topology" },
        Spec { name: "help", takes_value: false, help: "show usage" },
    ]
}

/// Build the deployment spec for a subcommand: `--config file.toml` (or
/// the default preset) as the base, CLI flags as an overlay on top.
fn spec_from_args(args: &Args, default_preset: &str) -> Result<DeploymentSpec> {
    let mut spec = match args.get("config") {
        Some(path) => DeploymentSpec::load(Path::new(path))?,
        None => presets::spec(default_preset).expect("known preset"),
    };
    if args.flag("full") {
        spec.network = flexspim::deploy::NetworkSpec::from_network(&scnn_dvs_gesture());
    }
    let parsed = |name: &str| -> Result<Option<usize>> {
        args.get_parsed::<usize>(name).map_err(|e| anyhow!(e))
    };
    if let Some(m) = parsed("macros")? {
        spec.substrate.macros = m;
    }
    if let Some(p) = args.get("policy") {
        spec.substrate.policy = parse_policy(p)?;
    }
    if let Some(v) = args.get_parsed::<f64>("vdd").map_err(|e| anyhow!(e))? {
        spec.substrate.vdd = v;
    }
    // Backend kind before seed: `--backend native --seed 7` on a PJRT
    // config must land the seed on the freshly-selected native backend.
    if let Some(kind) = args.get("backend") {
        let seed = spec.backend.seed().unwrap_or(42);
        spec.backend = match kind {
            "native" => BackendSpec::Native { seed },
            "native-dense" => BackendSpec::NativeDense { seed },
            // Keep a config's artifacts path when it already selected pjrt.
            "pjrt" => match spec.backend {
                BackendSpec::Pjrt { .. } => spec.backend.clone(),
                _ => BackendSpec::Pjrt { artifacts: None },
            },
            other => bail!("unknown backend '{other}' (native|native-dense|pjrt)"),
        };
    }
    if let Some(seed) = args.get_parsed::<u64>("seed").map_err(|e| anyhow!(e))? {
        match &mut spec.backend {
            BackendSpec::Native { seed: s } | BackendSpec::NativeDense { seed: s } => *s = seed,
            BackendSpec::Pjrt { .. } => {}
        }
    }
    if let Some(w) = parsed("workers")? {
        spec.serve.workers = w;
    }
    if let Some(kb) = args.get_parsed::<u64>("budget-kb").map_err(|e| anyhow!(e))? {
        spec.serve.resident_budget_kb = kb;
    }
    if args.flag("deterministic") {
        spec.serve.deterministic_admission = true;
    }
    if let Some(m) = args.get_parsed::<f64>("exit-margin").map_err(|e| anyhow!(e))? {
        spec.serve.early_exit_margin = m;
    }
    if let Some(step) = args.get_parsed::<u64>("step-us").map_err(|e| anyhow!(e))? {
        spec.serve.step_us = Some(step);
    }
    if let Some(frames) = parsed("frames-per-window")? {
        spec.serve.frames_per_window = Some(frames);
    }
    if args.flag("autoscale") {
        spec.serve.autoscale.enabled = true;
    }
    if let Some(max) = parsed("autoscale-max")? {
        spec.serve.autoscale.enabled = true;
        spec.serve.autoscale.max_workers = max;
    }
    if let Some(slo) = args.get_parsed::<f64>("slo-p99-ms").map_err(|e| anyhow!(e))? {
        spec.serve.autoscale.enabled = true;
        spec.serve.autoscale.slo_p99_ms = slo;
    }
    if args.flag("precision") {
        spec.precision.enabled = true;
    }
    if let Some(d) = args.get_parsed::<u32>("precision-max-delta").map_err(|e| anyhow!(e))? {
        spec.precision.enabled = true;
        spec.precision.max_delta = d;
    }
    if let Some(p) = args.get_parsed::<f64>("precision-p99-ms").map_err(|e| anyhow!(e))? {
        spec.precision.enabled = true;
        spec.precision.drop_p99_ms = p;
    }
    if let Some(m) = args.get_parsed::<f64>("precision-margin").map_err(|e| anyhow!(e))? {
        spec.precision.enabled = true;
        spec.precision.raise_margin = m;
    }
    if let Some(n) = parsed("nodes")? {
        spec.fleet.nodes = n;
    }
    if let Some(n) = parsed("max-nodes")? {
        spec.fleet.max_nodes = n;
    }
    if let Some(p) = args.get("placement") {
        spec.fleet.placement = flexspim::deploy::Placement::parse(p)?;
    }
    if args.flag("telemetry") || args.flag("dump-telemetry") {
        spec.telemetry.enabled = true;
    }
    if args.get("trace").is_some() {
        spec.telemetry.trace = true;
    }
    if let Some(n) = args.get_parsed::<u32>("trace-sample").map_err(|e| anyhow!(e))? {
        spec.telemetry.trace = true;
        spec.telemetry.trace_sample = n;
    }
    spec.validate()?;
    Ok(spec)
}

fn main() -> Result<()> {
    tlog::init_from_env();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&argv, &specs()) {
        Ok(a) => a,
        Err(e) => {
            log_error!("{e}\n{}", usage("flexspim <command>", &specs()));
            std::process::exit(2);
        }
    };
    if let Some(v) = args.get("verbosity") {
        match Level::parse(v) {
            Some(l) => tlog::set_level(l),
            None => bail!("unknown verbosity '{v}' (error|warn|info|debug|trace)"),
        }
    }
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("help");
    if args.flag("help") || cmd == "help" {
        log_info!("{}", usage("flexspim <command>", &specs()));
        log_info!("commands: reproduce run serve fleet train map simulate sweep");
        log_info!("presets:  {}", presets::names().join(" "));
        return Ok(());
    }
    match cmd {
        "reproduce" => reproduce(&args),
        "run" => run_inference(&args),
        "serve" => run_serve(&args),
        "fleet" => run_fleet(&args),
        "train" => run_training(&args),
        "map" => run_map(&args),
        "simulate" => run_simulate(&args),
        "sweep" => run_sweep(&args),
        other => bail!("unknown command '{other}' (try: flexspim help)"),
    }
}

/// Subcommands that are not config-driven must say so rather than
/// silently ignoring `--config`.
fn reject_config(args: &Args, cmd: &str) -> Result<()> {
    if args.get("config").is_some() {
        bail!(
            "--config applies to run/serve/map/sweep (deployment spec) and \
             train/simulate (train spec); '{cmd}' is driven by its own flags"
        );
    }
    Ok(())
}

fn reproduce(args: &Args) -> Result<()> {
    reject_config(args, "reproduce")?;
    let what = args.positional().get(1).map(|s| s.as_str()).unwrap_or("all");
    let mut any = false;
    if matches!(what, "fig4" | "all") {
        log_info!("{}", fig4::render(&fig4::run()));
        any = true;
    }
    if matches!(what, "fig6" | "all") {
        log_info!("{}", fig6::render_sizes());
        log_info!("(accuracy sweep: `flexspim sweep` — random weights give chance accuracy)\n");
        any = true;
    }
    if matches!(what, "fig7a" | "fig7cd" | "fig7" | "all") {
        let a = fig7::run_fig7a();
        log_info!("{}", fig7::render(&a, &fig7::run_fig7c(), &fig7::run_fig7d()));
        any = true;
    }
    if matches!(what, "table1" | "all") {
        log_info!("{}", table1::render());
        any = true;
    }
    if !any {
        bail!("unknown figure '{what}' (fig4|fig6|fig7a|fig7cd|table1|all)");
    }
    Ok(())
}

fn run_inference(args: &Args) -> Result<()> {
    let samples = args.get_or("samples", 2usize);
    let seed = args.get_or("seed", 42u64);

    let spec = spec_from_args(args, presets::SCNN_DVS_GESTURE)?;
    let deployment = spec.deploy()?;
    let mut coord = deployment.coordinator()?;
    let net = coord.network().clone();
    log_info!(
        "deploying {} on {} macros ({}, {} backend, {:.2} V)",
        net.name,
        deployment.spec().substrate.macros,
        deployment.spec().substrate.policy,
        deployment.spec().backend.kind(),
        deployment.spec().substrate.vdd,
    );
    log_info!("mapping:\n{}", coord.mapping().table(&net));

    let gen = GestureGenerator::default_48();
    let mut rng = Rng::new(seed);
    let data = gen.dataset(samples, &mut rng);
    log_info!("running {} samples ...", data.len());
    let metrics = coord.run_dataset(&data)?;
    log_info!("{}", metrics.report());
    Ok(())
}

fn run_serve(args: &Args) -> Result<()> {
    use flexspim::serve::gesture_traffic;

    let sessions = args.get_or("sessions", 16usize);
    let seed = args.get_or("seed", 42u64);
    let jitter_us = args.get_or("jitter-us", 8_000u64);

    let spec = spec_from_args(args, presets::SERVE_DEMO)?;
    let deployment = spec.deploy()?;
    let svc = deployment.service()?;
    log_info!(
        "serving {} on {} macros ({}): {sessions} sessions, {} workers, \
         {jitter_us} us arrival jitter, {} b vmem/session, {} b residency budget",
        deployment.network().name,
        deployment.spec().substrate.macros,
        deployment.spec().substrate.policy,
        svc.config().workers,
        svc.plan().net.total_vmem_bits(),
        svc.config().resident_budget_bits,
    );
    let auto = &svc.config().autoscale;
    if auto.enabled {
        log_info!(
            "autoscaler: {}..{} workers, p99 SLO {:.1} ms, tick {} ms, \
             queue-high {}/worker, hysteresis {}",
            auto.min_workers,
            auto.max_workers,
            auto.slo_p99_s * 1e3,
            auto.interval.as_millis(),
            auto.queue_high,
            auto.hysteresis_ticks,
        );
    }
    let prec = &svc.config().precision;
    if prec.enabled {
        log_info!(
            "precision controller: up to {} tiers, drop over p99 {:.1} ms or \
             queue {}/worker, raise under margin {:.2}",
            prec.max_delta,
            prec.drop_p99_s * 1e3,
            prec.queue_high,
            prec.raise_margin,
        );
    }
    let traffic = gesture_traffic(sessions, seed ^ 0x7EA4_11FC, jitter_us);
    let report = svc.serve(&traffic, 64)?;
    log_info!("{}", report.report());
    if args.flag("dump-telemetry") {
        log_info!("{}", svc.recorder().dump());
        log_info!("{}", svc.metrics().prometheus_text());
        log_info!("{}", flexspim::telemetry::metrics::global().prometheus_text());
        log_info!("TELEMETRY_JSON {}", svc.metrics().snapshot().to_json());
    }
    if let Some(path) = args.get("trace") {
        std::fs::write(path, flexspim::telemetry::trace::chrome_trace_json())?;
        log_info!("wrote Chrome trace to {path} (load it in Perfetto or chrome://tracing)");
    }
    Ok(())
}

fn run_fleet(args: &Args) -> Result<()> {
    use flexspim::serve::{gesture_traffic, ArrivalProcess, LoadConfig};

    let sessions = args.get_or("sessions", 16usize);
    let seed = args.get_or("seed", 42u64);
    let jitter_us = args.get_or("jitter-us", 8_000u64);
    let rate = args.get_or("rate", 200.0f64);
    let time_scale = args.get_or("time-scale", 10.0f64);

    let spec = spec_from_args(args, presets::FLEET_DEMO)?;
    let deployment = spec.deploy()?;
    let mut fleet = deployment.fleet()?;
    let fs = fleet.spec().clone();
    log_info!(
        "fleet-serving {} on {} nodes ({} placement, {} vnodes/node, \
         {:.0} pJ/bit link{}): {sessions} sessions at {rate:.0}/s, \
         {} workers/node, time scale {time_scale:.0}x",
        deployment.network().name,
        fs.nodes,
        fs.placement.key(),
        fs.vnodes,
        fs.link_pj_per_bit,
        if fs.max_nodes > 0 {
            format!(
                ", autoscale to {} over {} sessions/node",
                fs.max_nodes, fs.scale_high_sessions
            )
        } else {
            String::new()
        },
        fleet.node(0).config().workers,
    );
    let traffic = gesture_traffic(sessions, seed ^ 0x7EA4_11FC, jitter_us);
    let cfg = LoadConfig {
        arrivals: ArrivalProcess::Poisson { rate_per_sec: rate },
        time_scale,
        chunk: 64,
        seed,
    };
    let r = fleet.drive_open_loop(&traffic, &cfg)?;
    log_info!(
        "offered {:8.2} w/s  goodput {:8.2} w/s  max lag {:6.1} ms",
        r.offered_windows_per_sec,
        r.goodput_windows_per_sec,
        1e3 * r.max_lag_s,
    );
    log_info!("{}", r.fleet.report());
    if args.flag("dump-telemetry") {
        // The fleet registry (per-link traffic, per-node session gauges)
        // plus each live node's own serve registry.
        log_info!("{}", fleet.metrics().prometheus_text());
        for node in fleet.live_nodes() {
            log_info!("{}", fleet.node(node).metrics().prometheus_text());
        }
        log_info!("TELEMETRY_JSON {}", fleet.metrics().snapshot().to_json());
    }
    Ok(())
}

/// `train`/`simulate` config base: `--config file.toml` (strict
/// `[train]`/`[simulate]` sections) or the defaults, CLI flags on top.
fn train_spec_from_args(args: &Args) -> Result<flexspim::deploy::TrainSpec> {
    let mut spec = match args.get("config") {
        Some(path) => flexspim::deploy::TrainSpec::load(Path::new(path))?,
        None => flexspim::deploy::TrainSpec::default(),
    };
    let parsed = |name: &str| -> Result<Option<usize>> {
        args.get_parsed::<usize>(name).map_err(|e| anyhow!(e))
    };
    if let Some(s) = parsed("steps")? {
        spec.train.steps = s;
    }
    if let Some(lr) = args.get_parsed::<f32>("lr").map_err(|e| anyhow!(e))? {
        spec.train.lr = lr;
    }
    if let Some(s) = args.get_parsed::<u64>("seed").map_err(|e| anyhow!(e))? {
        spec.train.seed = s;
    }
    if let Some(o) = args.get("out") {
        spec.train.out = o.to_string();
    }
    if let Some(b) = args.get_parsed::<u32>("wbits").map_err(|e| anyhow!(e))? {
        spec.simulate.w_bits = b;
    }
    if let Some(b) = args.get_parsed::<u32>("pbits").map_err(|e| anyhow!(e))? {
        spec.simulate.p_bits = b;
    }
    if let Some(n) = args.get_parsed::<u32>("nc").map_err(|e| anyhow!(e))? {
        spec.simulate.n_c = n;
    }
    if let Some(n) = parsed("neurons")? {
        spec.simulate.neurons = n;
    }
    if let Some(f) = parsed("fanin")? {
        spec.simulate.fan_in = f;
    }
    spec.validate()?;
    Ok(spec)
}

fn run_training(args: &Args) -> Result<()> {
    let tc = train_spec_from_args(args)?.train;
    let (steps, lr) = (tc.steps, tc.lr);

    let rt = Runtime::cpu()?;
    let dir = artifacts_dir();
    let mut trainer = TrainRunner::load(&rt, &dir)?;
    let gen = GestureGenerator::default_48();
    let mut rng = Rng::new(tc.seed);
    log_info!("training {steps} steps (batch 4, lr {lr}) ...");
    for step in 0..steps {
        let (frames, labels) = flexspim::runtime::trainer::synth_batch(&gen, &mut rng);
        let m = trainer.step(&frames, &labels, lr)?;
        if step % 10 == 0 || step == steps - 1 {
            log_info!("step {step:4}  loss {:.4}  batch-acc {:.2}", m.loss, m.accuracy);
        }
    }
    save_weight_file(&trainer.to_weight_file(), std::path::Path::new(&tc.out))?;
    log_info!("wrote {}", tc.out);
    Ok(())
}

/// Serialize a WeightFile in the FSPW format (mirror of train.py).
fn save_weight_file(wf: &flexspim::runtime::WeightFile, path: &Path) -> Result<()> {
    use std::io::Write;
    let mut f = std::fs::File::create(path)?;
    f.write_all(b"FSPW")?;
    f.write_all(&(wf.layers.len() as i32).to_le_bytes())?;
    for l in &wf.layers {
        f.write_all(&(l.name.len() as i32).to_le_bytes())?;
        f.write_all(l.name.as_bytes())?;
        f.write_all(&(l.w_bits as i32).to_le_bytes())?;
        f.write_all(&(l.p_bits as i32).to_le_bytes())?;
        f.write_all(&(l.dims.len() as i32).to_le_bytes())?;
        for &d in &l.dims {
            f.write_all(&(d as i32).to_le_bytes())?;
        }
        for &v in &l.data {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

fn run_map(args: &Args) -> Result<()> {
    use flexspim::dataflow::{Mapper, Policy};

    let spec = spec_from_args(args, presets::SCNN_DVS_GESTURE)?;
    let net = spec.network.build()?;
    let macros = spec.substrate.macros;
    let mapper = Mapper::flexspim(macros);
    for policy in Policy::ALL {
        let m = mapper.map(&net, policy);
        log_info!("=== {} — {policy} ({macros} macros) ===", net.name);
        log_info!("{}", m.table(&net));
    }
    Ok(())
}

fn run_simulate(args: &Args) -> Result<()> {
    let sc = train_spec_from_args(args)?.simulate;
    let (w_bits, p_bits, n_c) = (sc.w_bits, sc.p_bits, sc.n_c);
    let (neurons, fan_in) = (sc.neurons, sc.fan_in);

    let cfg = MacroConfig::flexspim(w_bits, p_bits, n_c, fan_in, neurons);
    cfg.validate().map_err(|e| anyhow::anyhow!(e))?;
    let mut mac = CimMacro::new(cfg).map_err(|e| anyhow::anyhow!(e))?;
    let mut rng = Rng::new(1);
    for n in 0..neurons {
        for j in 0..fan_in {
            mac.load_weight(
                n,
                j,
                rng.range_i64(
                    flexspim::snn::quant::min_val(w_bits),
                    flexspim::snn::quant::max_val(w_bits),
                ),
            );
        }
    }
    mac.reset_counters();
    let spikes: Vec<bool> = (0..fan_in).map(|_| rng.chance(0.5)).collect();
    let theta = flexspim::snn::quant::max_val(p_bits) / 2;
    let out = mac.timestep(&spikes, theta);
    let c = *mac.counters();
    let model = MacroEnergyModel::nominal();
    log_info!("macro {w_bits}b/{p_bits}b shape N_C={n_c}, {neurons} neurons × {fan_in} synapses");
    log_info!("input spikes: {spikes:?}");
    log_info!("output spikes: {} fired of {neurons}", out.iter().filter(|&&b| b).count());
    log_info!(
        "cycles {}  adder-ops {}  carry-hops {}  writebacks {}",
        c.cim_cycles, c.adder_ops, c.carry_hops, c.writebacks
    );
    log_info!(
        "energy: {:.3} pJ total, {:.3} pJ/SOP",
        model.price_pj(&c),
        model.pj_per_sop(&c)
    );
    Ok(())
}

fn run_sweep(args: &Args) -> Result<()> {
    let samples = args.get_or("samples", 2usize);
    let seed = args.get_or("seed", 42u64);

    let spec = spec_from_args(args, presets::SCNN_DVS_GESTURE)?;
    let deployment = spec.deploy()?;
    let mut coord = deployment.coordinator()?;
    let gen = GestureGenerator::default_48();
    let mut rng = Rng::new(seed);
    let data = gen.dataset(samples, &mut rng);
    let configs = fig6::scaling_configs_for(coord.network());
    log_info!(
        "sweeping {} on {} configs × {} samples ...",
        deployment.network().name,
        configs.len(),
        data.len()
    );
    let points = fig6::accuracy_sweep(&mut coord, &data, &configs)?;
    log_info!("{}", fig6::render_sweep(&points));
    log_info!("{}", fig6::render_sizes());
    Ok(())
}
