//! FlexSpIM command-line interface.
//!
//! ```text
//! flexspim reproduce <fig4|fig6|fig7a|fig7cd|table1|all>
//! flexspim run       [--samples N] [--macros M] [--policy P] [--seed S]
//! flexspim serve     [--sessions N] [--workers W] [--jitter-us J]
//!                    [--budget-kb B] [--macros M] [--policy P] [--seed S] [--full]
//!                    [--deterministic] [--exit-margin X]
//! flexspim train     [--steps N] [--lr X] [--seed S] [--out PATH]
//! flexspim map       [--macros M]
//! flexspim simulate  [--wbits W] [--pbits P] [--nc C] [--neurons N] [--fanin F]
//! flexspim sweep     [--samples N] [--seed S]      # Fig. 6(b) accuracy
//! ```
//!
//! `run`, `train`, and `sweep` need the AOT artifacts (`make artifacts`);
//! `serve` drives the streaming tier on the pure-Rust backend and runs
//! everywhere.

use anyhow::{bail, Result};
use flexspim::cim::{CimMacro, MacroConfig};
use flexspim::coordinator::Coordinator;
use flexspim::dataflow::{Mapper, Policy};
use flexspim::energy::MacroEnergyModel;
use flexspim::events::GestureGenerator;
use flexspim::figures::{fig4, fig6, fig7, table1};
use flexspim::runtime::{artifacts_dir, Runtime, TrainRunner};
use flexspim::snn::network::scnn_dvs_gesture;
use flexspim::util::cli::{usage, Args, Spec};
use flexspim::util::rng::Rng;

fn specs() -> Vec<Spec> {
    vec![
        Spec { name: "samples", takes_value: true, help: "samples per class (default 2)" },
        Spec { name: "macros", takes_value: true, help: "number of CIM macros (default 16)" },
        Spec { name: "policy", takes_value: true, help: "ws-only|os-only|hs-min|hs-max|hs-opt" },
        Spec { name: "seed", takes_value: true, help: "rng seed (default 42)" },
        Spec { name: "steps", takes_value: true, help: "training steps (default 100)" },
        Spec { name: "lr", takes_value: true, help: "learning rate (default 0.05)" },
        Spec { name: "out", takes_value: true, help: "output path for trained weights" },
        Spec { name: "wbits", takes_value: true, help: "weight bits (simulate)" },
        Spec { name: "pbits", takes_value: true, help: "membrane bits (simulate)" },
        Spec { name: "nc", takes_value: true, help: "operand columns N_C (simulate)" },
        Spec { name: "neurons", takes_value: true, help: "parallel neurons (simulate)" },
        Spec { name: "fanin", takes_value: true, help: "synapses per neuron (simulate)" },
        Spec { name: "sessions", takes_value: true, help: "streaming sessions (serve, default 16)" },
        Spec { name: "workers", takes_value: true, help: "serve worker threads (default 4)" },
        Spec { name: "jitter-us", takes_value: true, help: "arrival jitter in us (serve)" },
        Spec { name: "budget-kb", takes_value: true, help: "vmem budget kB (serve, 0 = chip)" },
        Spec {
            name: "deterministic",
            takes_value: false,
            help: "serve: dispatch in admission order (reproducible residency)",
        },
        Spec {
            name: "exit-margin",
            takes_value: true,
            help: "serve: early-exit confidence margin (0 = off)",
        },
        Spec { name: "full", takes_value: false, help: "serve the full paper SCNN" },
        Spec { name: "config", takes_value: true, help: "TOML config file" },
        Spec { name: "help", takes_value: false, help: "show usage" },
    ]
}

fn parse_policy(s: &str) -> Result<Policy> {
    Ok(match s {
        "ws-only" => Policy::WsOnly,
        "os-only" => Policy::OsOnly,
        "hs-min" => Policy::HsMin,
        "hs-max" => Policy::HsMax,
        "hs-opt" => Policy::HsOpt,
        other => bail!("unknown policy '{other}'"),
    })
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&argv, &specs()) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{}", usage("flexspim <command>", &specs()));
            std::process::exit(2);
        }
    };
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("help");
    if args.flag("help") || cmd == "help" {
        println!("{}", usage("flexspim <command>", &specs()));
        println!("commands: reproduce run serve train map simulate sweep");
        return Ok(());
    }
    match cmd {
        "reproduce" => reproduce(&args),
        "run" => run_inference(&args),
        "serve" => run_serve(&args),
        "train" => run_training(&args),
        "map" => run_map(&args),
        "simulate" => run_simulate(&args),
        "sweep" => run_sweep(&args),
        other => bail!("unknown command '{other}' (try: flexspim help)"),
    }
}

fn reproduce(args: &Args) -> Result<()> {
    let what = args.positional().get(1).map(|s| s.as_str()).unwrap_or("all");
    let mut any = false;
    if matches!(what, "fig4" | "all") {
        println!("{}", fig4::render(&fig4::run()));
        any = true;
    }
    if matches!(what, "fig6" | "all") {
        println!("{}", fig6::render_sizes());
        println!("(accuracy sweep: `flexspim sweep` — needs artifacts + trained weights)\n");
        any = true;
    }
    if matches!(what, "fig7a" | "fig7cd" | "fig7" | "all") {
        let a = fig7::run_fig7a();
        println!("{}", fig7::render(&a, &fig7::run_fig7c(), &fig7::run_fig7d()));
        any = true;
    }
    if matches!(what, "table1" | "all") {
        println!("{}", table1::render());
        any = true;
    }
    if !any {
        bail!("unknown figure '{what}' (fig4|fig6|fig7a|fig7cd|table1|all)");
    }
    Ok(())
}

fn run_inference(args: &Args) -> Result<()> {
    let samples = args.get_or("samples", 2usize);
    let macros = args.get_or("macros", 16usize);
    let policy = parse_policy(&args.get_or("policy", "hs-opt".to_string()))?;
    let seed = args.get_or("seed", 42u64);

    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let dir = artifacts_dir();
    let runner = flexspim::runtime::ScnnRunner::load(&rt, &dir)?;
    let mut coord = Coordinator::with_runner(runner, macros, policy)?;
    let net = coord.network().clone();
    println!("mapping ({} macros, {policy}):\n{}", macros, coord.mapping().table(&net));

    let gen = GestureGenerator::default_48();
    let mut rng = Rng::new(seed);
    let data = gen.dataset(samples, &mut rng);
    println!("running {} samples ...", data.len());
    let metrics = coord.run_dataset(&data)?;
    println!("{}", metrics.report());
    Ok(())
}

/// Compact serve demo net: 16 timesteps over the 48×48 substrate, so each
/// 100-ms session streams as 4 micro-windows of 4 frames.
fn serve_demo_net() -> flexspim::snn::Network {
    use flexspim::snn::{LayerSpec, Network, Resolution};
    let r = Resolution::new(4, 9);
    Network::new(
        "serve-demo",
        vec![
            LayerSpec::conv("C1", 2, 8, 3, 4, 1, 48, 48, r),
            LayerSpec::fc("F1", 8 * 12 * 12, 64, r),
            LayerSpec::fc("F2", 64, 10, Resolution::new(5, 10)),
        ],
        16,
    )
}

fn run_serve(args: &Args) -> Result<()> {
    use flexspim::serve::{gesture_traffic, ServiceConfig, StreamingService};

    let sessions = args.get_or("sessions", 16usize);
    let workers = args.get_or("workers", 4usize);
    let macros = args.get_or("macros", 16usize);
    let policy = parse_policy(&args.get_or("policy", "hs-opt".to_string()))?;
    let seed = args.get_or("seed", 42u64);
    let jitter_us = args.get_or("jitter-us", 8_000u64);
    let budget_kb = args.get_or("budget-kb", 0u64);

    let net = if args.flag("full") { scnn_dvs_gesture() } else { serve_demo_net() };
    let mut cfg = ServiceConfig::nominal(workers);
    if budget_kb > 0 {
        cfg.resident_budget_bits = budget_kb * 1024 * 8;
    }
    cfg.deterministic_admission = args.flag("deterministic");
    cfg.early_exit_margin = args.get_or("exit-margin", 0.0f64);
    let svc = StreamingService::native(net.clone(), seed, macros, policy, cfg);
    println!(
        "serving {} on {macros} macros ({policy}): {sessions} sessions, {workers} workers, \
         {jitter_us} us arrival jitter, {} b vmem/session, {} b residency budget",
        net.name,
        svc.plan().net.total_vmem_bits(),
        svc.config().resident_budget_bits,
    );
    let traffic = gesture_traffic(sessions, seed ^ 0x7EA4_11FC, jitter_us);
    let report = svc.serve(&traffic, 64)?;
    println!("{}", report.report());
    Ok(())
}

fn run_training(args: &Args) -> Result<()> {
    let steps = args.get_or("steps", 100usize);
    let lr = args.get_or("lr", 0.05f32);
    let seed = args.get_or("seed", 42u64);
    let out = args.get_or("out", String::from("artifacts/weights_trained.bin"));

    let rt = Runtime::cpu()?;
    let dir = artifacts_dir();
    let mut trainer = TrainRunner::load(&rt, &dir)?;
    let gen = GestureGenerator::default_48();
    let mut rng = Rng::new(seed);
    println!("training {steps} steps (batch 4, lr {lr}) ...");
    for step in 0..steps {
        let (frames, labels) = flexspim::runtime::trainer::synth_batch(&gen, &mut rng);
        let m = trainer.step(&frames, &labels, lr)?;
        if step % 10 == 0 || step == steps - 1 {
            println!("step {step:4}  loss {:.4}  batch-acc {:.2}", m.loss, m.accuracy);
        }
    }
    save_weight_file(&trainer.to_weight_file(), std::path::Path::new(&out))?;
    println!("wrote {out}");
    Ok(())
}

/// Serialize a WeightFile in the FSPW format (mirror of train.py).
fn save_weight_file(wf: &flexspim::runtime::WeightFile, path: &std::path::Path) -> Result<()> {
    use std::io::Write;
    let mut f = std::fs::File::create(path)?;
    f.write_all(b"FSPW")?;
    f.write_all(&(wf.layers.len() as i32).to_le_bytes())?;
    for l in &wf.layers {
        f.write_all(&(l.name.len() as i32).to_le_bytes())?;
        f.write_all(l.name.as_bytes())?;
        f.write_all(&(l.w_bits as i32).to_le_bytes())?;
        f.write_all(&(l.p_bits as i32).to_le_bytes())?;
        f.write_all(&(l.dims.len() as i32).to_le_bytes())?;
        for &d in &l.dims {
            f.write_all(&(d as i32).to_le_bytes())?;
        }
        for &v in &l.data {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

fn run_map(args: &Args) -> Result<()> {
    let macros = args.get_or("macros", 2usize);
    let net = scnn_dvs_gesture();
    let mapper = Mapper::flexspim(macros);
    for policy in Policy::ALL {
        let m = mapper.map(&net, policy);
        println!("=== {policy} ({macros} macros) ===");
        println!("{}", m.table(&net));
    }
    Ok(())
}

fn run_simulate(args: &Args) -> Result<()> {
    let w_bits = args.get_or("wbits", 8u32);
    let p_bits = args.get_or("pbits", 16u32);
    let n_c = args.get_or("nc", 1u32);
    let neurons = args.get_or("neurons", 32usize);
    let fan_in = args.get_or("fanin", 4usize);

    let cfg = MacroConfig::flexspim(w_bits, p_bits, n_c, fan_in, neurons);
    cfg.validate().map_err(|e| anyhow::anyhow!(e))?;
    let mut mac = CimMacro::new(cfg).map_err(|e| anyhow::anyhow!(e))?;
    let mut rng = Rng::new(1);
    for n in 0..neurons {
        for j in 0..fan_in {
            mac.load_weight(
                n,
                j,
                rng.range_i64(
                    flexspim::snn::quant::min_val(w_bits),
                    flexspim::snn::quant::max_val(w_bits),
                ),
            );
        }
    }
    mac.reset_counters();
    let spikes: Vec<bool> = (0..fan_in).map(|_| rng.chance(0.5)).collect();
    let theta = flexspim::snn::quant::max_val(p_bits) / 2;
    let out = mac.timestep(&spikes, theta);
    let c = *mac.counters();
    let model = MacroEnergyModel::nominal();
    println!("macro {w_bits}b/{p_bits}b shape N_C={n_c}, {neurons} neurons × {fan_in} synapses");
    println!("input spikes: {spikes:?}");
    println!("output spikes: {} fired of {neurons}", out.iter().filter(|&&b| b).count());
    println!(
        "cycles {}  adder-ops {}  carry-hops {}  writebacks {}",
        c.cim_cycles, c.adder_ops, c.carry_hops, c.writebacks
    );
    println!(
        "energy: {:.3} pJ total, {:.3} pJ/SOP",
        model.price_pj(&c),
        model.pj_per_sop(&c)
    );
    Ok(())
}

fn run_sweep(args: &Args) -> Result<()> {
    let samples = args.get_or("samples", 2usize);
    let seed = args.get_or("seed", 42u64);
    let rt = Runtime::cpu()?;
    let dir = artifacts_dir();
    let runner = flexspim::runtime::ScnnRunner::load(&rt, &dir)?;
    let mut coord = Coordinator::with_runner(runner, 16, Policy::HsOpt)?;
    let gen = GestureGenerator::default_48();
    let mut rng = Rng::new(seed);
    let data = gen.dataset(samples, &mut rng);
    let configs = fig6::scaling_configs();
    println!("sweeping {} configs × {} samples ...", configs.len(), data.len());
    let points = fig6::accuracy_sweep(&mut coord, &data, &configs)?;
    println!("{}", fig6::render_sweep(&points));
    println!("{}", fig6::render_sizes());
    Ok(())
}
