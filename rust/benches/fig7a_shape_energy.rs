//! Bench: regenerate Fig. 7(a) — energy vs resolution linearity and the
//! shape-dependent energy study — and time the bit-accurate macro
//! simulator that produces it.
//!
//! ```sh
//! cargo bench --bench fig7a_shape_energy
//! ```

use flexspim::cim::{CimMacro, MacroConfig};
use flexspim::figures::fig7;
use flexspim::util::bench::{section, Bench};
use flexspim::util::rng::Rng;

fn main() {
    section("Fig. 7(a) — reproduction output");
    let a = fig7::run_fig7a();
    // Render only the 7(a) part here (c/d have their own bench).
    println!("bits -> pJ/SOP (single-row shapes):");
    for p in &a.resolution_sweep {
        println!("  {:>2}b  {:>7.3}", p.bits, p.pj_per_sop);
    }
    println!("shape -> pJ/SOP (8b/16b, 32 channels, bit-accurate sim):");
    for p in &a.shape_sweep {
        println!("  {:>2}x{:<2} {:>7.3}", p.n_r, p.n_c, p.pj_per_sop);
    }
    println!(
        "row-wise baseline {:.3} pJ/SOP | saving {:.2}x-{:.2}x (paper: up to 4.3x) | variation {:.1} % (paper < 24 %)",
        a.rowwise_baseline_pj,
        a.min_saving(),
        a.max_saving(),
        100.0 * a.shape_variation()
    );

    section("macro simulator timing (one cim_accumulate, 32 neurons)");
    let b = Bench::default();
    for n_c in [1u32, 2, 4, 8, 16] {
        let neurons = (256 / n_c as usize).min(32);
        let cfg = MacroConfig::flexspim(8, 16, n_c, 1, neurons);
        let mut mac = CimMacro::new(cfg).unwrap();
        let mut rng = Rng::new(3);
        for n in 0..neurons {
            mac.load_weight(n, 0, rng.range_i64(-127, 127));
        }
        b.report(&format!("cim_accumulate 8b/16b N_C={n_c}"), || {
            mac.cim_accumulate(0, None);
            mac.counters().sops
        });
    }

    section("full figure regeneration timing");
    b.report("fig7a end-to-end", fig7::run_fig7a);
}
