//! Bench: regenerate Fig. 6 — resolution flexibility vs model footprint —
//! and time the arbitrary-resolution quantizer.
//!
//! The accuracy axis needs the PJRT runtime + trained weights and lives in
//! `flexspim sweep`; this bench covers the size/quantization axes, which
//! are what the hardware flexibility enables.
//!
//! ```sh
//! cargo bench --bench fig6_resolution_sweep
//! ```

use flexspim::figures::fig6;
use flexspim::runtime::{artifacts_dir, WeightFile};
use flexspim::snn::network::scnn_dvs_gesture;
use flexspim::snn::Resolution;
use flexspim::util::bench::{section, Bench};

fn main() {
    section("Fig. 6(a) — reproduction output");
    println!("{}", fig6::render_sizes());

    section("Fig. 6(b) — size axis of the scaling sweep");
    let base = scnn_dvs_gesture();
    let base_bits = base.conv_weight_bits();
    for (label, res) in fig6::scaling_configs() {
        let net = base.with_resolutions(
            &res.iter().map(|&(w, p)| Resolution::new(w, p)).collect::<Vec<_>>(),
        );
        println!(
            "  {label:<10} conv {:>8} bits  ({:+.1} % vs base)",
            net.conv_weight_bits(),
            100.0 * (net.conv_weight_bits() as f64 / base_bits as f64 - 1.0)
        );
    }

    section("quantizer timing (requires artifacts/weights.bin)");
    let wpath = artifacts_dir().join("weights.bin");
    if wpath.exists() {
        let wf = WeightFile::load(&wpath).unwrap();
        let b = Bench::default();
        b.report("quantize all layers @ default res", || wf.quantize_default());
        b.report("quantize all layers @ 3b/8b", || {
            wf.quantize_at(&[(3, 8); 9])
        });
        // Bitwise granularity: every (w, p) in a small grid must work.
        b.report("quantize grid 2..8 x 6..12 (FC3 only)", || {
            let l = &wf.layers[8];
            let mut acc = 0i64;
            for w in 2..=8u32 {
                for p in 6..=12u32 {
                    let (q, _) = l.quantize(w, p);
                    acc += q[0] as i64;
                }
            }
            acc
        });
    } else {
        println!("  skipped: run `make artifacts` first");
    }
}
