//! Bench: regenerate Fig. 7(c)/(d) — many-macro system-level energy gains
//! vs the [4] and IMPULSE [3] baselines across the sparsity sweep.
//!
//! ```sh
//! cargo bench --bench fig7cd_system_extrapolation
//! ```

use flexspim::energy::baselines::{fig7c_gain_sweep, fig7d_gain_sweep};
use flexspim::util::bench::{section, Bench};

fn main() {
    section("Fig. 7(c) — FlexSpIM (16 macros, HS, optimal res) vs [4]");
    for (s, g) in fig7c_gain_sweep(&[0.85, 0.88, 0.91, 0.94, 0.97, 0.99]) {
        println!("  sparsity {s:.2}: gain {:.1} %  (paper: 87-90 %)", 100.0 * g);
    }

    section("Fig. 7(d) — FlexSpIM (18 macros, 6b/11b) vs IMPULSE [3]");
    for (s, g) in fig7d_gain_sweep(&[0.85, 0.88, 0.91, 0.94, 0.97, 0.99]) {
        println!("  sparsity {s:.2}: gain {:.1} %  (paper: 79-86 %)", 100.0 * g);
    }

    section("macro-count ablation (gain vs [4] at 95 % sparsity)");
    // DESIGN.md calls out the "more macros -> more stationarity" design
    // choice; sweep it.
    for macros in [4usize, 8, 16, 32] {
        let flex = flexspim::energy::SystemEnergyModel::flexspim(macros);
        let base = flexspim::energy::baselines::isscc24_system(macros);
        let flex_net = flexspim::energy::baselines::system_workload();
        let base_net = flexspim::energy::baselines::system_workload_isscc24();
        let fm = flexspim::dataflow::Mapper {
            macro_capacity_bits: flex.cfg.macro_bits,
            num_macros: macros,
        }
        .map(&flex_net, flexspim::dataflow::Policy::HsOpt);
        let bm = flexspim::dataflow::Mapper {
            macro_capacity_bits: base.cfg.macro_bits,
            num_macros: macros,
        }
        .map(&base_net, flexspim::dataflow::Policy::WsOnly);
        let ef = flex.evaluate(&flex_net, &fm, 0.95, None).total_pj();
        let eb = base.evaluate(&base_net, &bm, 0.95, Some(1)).total_pj();
        println!("  {macros:>3} macros: gain {:.1} %", 100.0 * (1.0 - ef / eb));
    }

    section("timing");
    let b = Bench::default();
    b.report("fig7c full sweep", || fig7c_gain_sweep(&[0.85, 0.92, 0.99]));
    b.report("fig7d full sweep", || fig7d_gain_sweep(&[0.85, 0.92, 0.99]));
}
