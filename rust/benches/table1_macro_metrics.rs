//! Bench: regenerate Table I (macro-level comparison) and time the
//! simulator at the Table I reference configuration.
//!
//! ```sh
//! cargo bench --bench table1_macro_metrics
//! ```

use flexspim::cim::ops::OperatingPoint;
use flexspim::cim::{CimMacro, MacroConfig};
use flexspim::energy::MacroEnergyModel;
use flexspim::figures::table1;
use flexspim::util::bench::{section, Bench};
use flexspim::util::rng::Rng;

fn main() {
    section("Table I — reproduction output");
    println!("{}", table1::render());

    section("reference-configuration simulation timing");
    // Table I reference point: 8b weights / 16b potentials, bit-serial,
    // 256 parallel neurons — one full accumulate is 16 row-cycles over
    // 256 columns = 4096 bit-ops through the PC adders.
    let cfg = MacroConfig::flexspim(8, 16, 1, 1, 256);
    let mut mac = CimMacro::new(cfg).unwrap();
    let mut rng = Rng::new(1);
    for n in 0..256 {
        mac.load_weight(n, 0, rng.range_i64(-127, 127));
    }
    let b = Bench::default();
    let m = b.report("cim_accumulate (256 SOPs)", || {
        mac.cim_accumulate(0, None);
    });
    let sim_sops_per_s = 256.0 / m.median_s();
    let silicon_sops = cfg.peak_sops(OperatingPoint::nominal().system_clock_hz);
    println!(
        "simulator speed: {:.2} M SOP/s host  (silicon: {:.2} G SOP/s; slowdown {:.0}x)",
        sim_sops_per_s / 1e6,
        silicon_sops / 1e9,
        silicon_sops / sim_sops_per_s
    );

    b.report("cim_fire (256 neurons)", || {
        mac.cim_fire(1000);
    });

    section("energy-model pricing timing");
    let model = MacroEnergyModel::nominal();
    let counters = *mac.counters();
    b.report("price_pj(ledger)", || model.price_pj(&counters));
    b.report("sop_pj_analytic 8b/16b", || {
        model.sop_pj_analytic(8, 16, 1, 256, 256).total_pj()
    });
}
