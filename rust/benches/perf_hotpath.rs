//! Perf-tracking bench: the L3 hot paths, measured the same way before
//! and after each optimization (EXPERIMENTS.md §Perf).
//!
//! Hot paths, in order of end-to-end weight:
//!   1. `CimMacro::cim_accumulate` — the bit-level simulator inner loop
//!      (dominates `flexspim simulate`, Fig. 7a regeneration, and all
//!      macro-level studies).
//!   2. `CimMacro::cim_fire` — comparison + conditional subtract pass.
//!   3. `Mapper::map` — the HS-opt search (dominates dataflow sweeps).
//!   4. `SystemEnergyModel::evaluate` — the system extrapolation kernel.
//!   5. Event generation + encoding — the data path feeding inference.
//!
//! ```sh
//! cargo bench --bench perf_hotpath
//! ```

use flexspim::cim::{CimMacro, MacroConfig};
use flexspim::coordinator::engine::SampleBuffers;
use flexspim::dataflow::{Mapper, Policy};
use flexspim::deploy::DeploymentSpec;
use flexspim::energy::SystemEnergyModel;
use flexspim::events::{encode_frames, encode_frames_sparse, GestureClass, GestureGenerator};
use flexspim::snn::events::{EventConvLayer, EventFcLayer, SpikeList};
use flexspim::snn::network::scnn_dvs_gesture;
use flexspim::snn::quant::{max_val, min_val};
use flexspim::snn::{LayerSpec, Resolution};
use flexspim::util::bench::{emit_json, quick_mode, section, Bench};
use flexspim::util::rng::Rng;

fn main() {
    let b = Bench::default();

    section("1+2. CIM macro simulator");
    for (w, p, n_c, neurons, label) in [
        (8u32, 16u32, 1u32, 256usize, "8b/16b serial x256"),
        (8, 16, 4, 64, "8b/16b 4x4 x64"),
        (4, 9, 3, 85, "4b/9b 3-col x85"),
        (16, 32, 8, 32, "16b/32b 8-col x32"),
    ] {
        let cfg = MacroConfig::flexspim(w, p, n_c, 1, neurons);
        let mut mac = CimMacro::new(cfg).unwrap();
        let mut rng = Rng::new(7);
        for n in 0..neurons {
            mac.load_weight(
                n,
                0,
                rng.range_i64(
                    flexspim::snn::quant::min_val(w),
                    flexspim::snn::quant::max_val(w),
                ),
            );
        }
        let m = b.report(&format!("accumulate {label}"), || {
            mac.cim_accumulate(0, None);
        });
        println!(
            "    -> {:.1} ns/SOP, {:.1} ns/bit-op",
            m.median_s() * 1e9 / neurons as f64,
            m.median_s() * 1e9 / (neurons as f64 * p as f64)
        );
        b.report(&format!("fire       {label}"), || {
            mac.cim_fire(50);
        });
    }

    section("3. dataflow mapping search");
    let net = scnn_dvs_gesture();
    for macros in [2usize, 16] {
        let mapper = Mapper::flexspim(macros);
        b.report(&format!("HS-opt search @ {macros} macros"), || {
            mapper.map(&net, Policy::HsOpt).used_bits
        });
    }

    section("4. system energy evaluation");
    let mapping = Mapper::flexspim(16).map(&net, Policy::HsOpt);
    let sys = SystemEnergyModel::flexspim(16);
    b.report("evaluate full net @ 95 % sparsity", || {
        sys.evaluate(&net, &mapping, 0.95, None).total_pj()
    });
    b.report("sop_pj best-shape search 8b/16b", || {
        sys.sop_pj(8, 16, None)
    });

    section("5. event generation + encoding");
    let gen = GestureGenerator::default_48();
    let mut rng = Rng::new(11);
    b.report("generate gesture sample", || {
        gen.sample(GestureClass::ArmRoll, &mut rng).events.len()
    });
    let stream = gen.sample(GestureClass::ArmRoll, &mut Rng::new(5));
    b.report("encode 16 frames (dense)", || encode_frames(&stream, 16).len());
    b.report("encode 16 frames (sparse)", || {
        encode_frames_sparse(&stream, 16).len()
    });

    // The CI `telemetry-overhead` smoke step gates on the emitted
    // overhead_pct (scripts/check_overhead.sh): instrumentation at its
    // default sampling must stay within 5 % of the uninstrumented path.
    section("6. telemetry overhead on the window hot path");
    let dep = DeploymentSpec::builder("telemetry-overhead")
        .timesteps(16)
        .conv("C1", 2, 4, 3, 4, 1, 48, 48, Resolution::new(4, 9))
        .fc("F1", 4 * 12 * 12, 10, Resolution::new(5, 10))
        .macros(2)
        .native_backend(7)
        .build()
        .unwrap()
        .deploy()
        .unwrap();
    let plan = dep.plan().clone();
    let mut backend = dep.backend().unwrap();
    let frames = encode_frames_sparse(&stream, 16);
    let mut bufs = SampleBuffers::default();
    let mut rate = vec![0i64; 10];
    let off = b.report("run_frames x16, telemetry off", || {
        rate.iter_mut().for_each(|r| *r = 0);
        plan.run_frames(backend.as_mut(), &mut bufs, &frames, &mut rate)
            .unwrap()
            .sops
    });
    flexspim::telemetry::set_enabled(true);
    flexspim::telemetry::trace::set_tracing(true, 64);
    let on = b.report("run_frames x16, telemetry on (sample 64)", || {
        rate.iter_mut().for_each(|r| *r = 0);
        plan.run_frames(backend.as_mut(), &mut bufs, &frames, &mut rate)
            .unwrap()
            .sops
    });
    flexspim::telemetry::trace::set_tracing(false, 64);
    let overhead_pct = (on.median_s() / off.median_s() - 1.0) * 100.0;
    println!("    -> telemetry overhead {overhead_pct:.2} % (median over median)");
    emit_json(
        "telemetry_overhead",
        &[
            ("off_us", off.median_s() * 1e6),
            ("on_us", on.median_s() * 1e6),
            ("overhead_pct", overhead_pct),
        ],
    );

    // The CI `packed-speedup` smoke step gates on the emitted speedups
    // (scripts/check_speedup.sh): the packed word-parallel kernels must
    // beat the scalar sparse reference at moderate activity.
    section("7. packed word-parallel SNN step vs scalar sparse step");
    let quick = quick_mode();
    let steps = 8usize;

    // Conv layer: packed row-add scatter + bitmask fire-check vs the
    // per-spike stamp/generation scalar path, on one weight set.
    let side = if quick { 16 } else { 24 };
    let res = Resolution::new(4, 9);
    let spec = LayerSpec::conv("P", 8, 16, 3, 1, 1, side, side, res);
    let mut wrng = Rng::new(17);
    let (lo, hi) = (min_val(res.w_bits), max_val(res.w_bits));
    let cw: Vec<i64> = (0..spec.num_weights()).map(|_| wrng.range_i64(lo, hi)).collect();
    let mut conv_packed = EventConvLayer::new(spec.clone(), cw.clone(), 40);
    let mut conv_scalar = EventConvLayer::new(spec, cw, 40);
    let conv_in = 8 * side * side;
    let mut out = SpikeList::default();
    for activity in [0.1f64, 0.25] {
        let mut rng = Rng::new(23);
        let frames: Vec<SpikeList> = (0..steps)
            .map(|_| {
                let bits: Vec<bool> = (0..conv_in).map(|_| rng.chance(activity)).collect();
                SpikeList::from_dense(&bits)
            })
            .collect();
        // Bit-identity sanity at bench scale before timing anything.
        conv_packed.reset();
        conv_scalar.reset();
        for f in &frames {
            assert_eq!(conv_packed.step(f), conv_scalar.step_scalar(f));
        }
        conv_packed.reset();
        let p = b.report(&format!("conv packed x{steps} @ {activity}"), || {
            let mut spikes = 0usize;
            for f in &frames {
                conv_packed.step_into(f, &mut out);
                spikes += out.count();
            }
            spikes
        });
        conv_scalar.reset();
        let s = b.report(&format!("conv scalar x{steps} @ {activity}"), || {
            let mut spikes = 0usize;
            for f in &frames {
                conv_scalar.step_scalar_into(f, &mut out);
                spikes += out.count();
            }
            spikes
        });
        let speedup = s.median_s() / p.median_s();
        println!("    -> packed conv speedup {speedup:.2}x @ {activity} activity");
        emit_json(
            "packed_step_conv",
            &[
                ("activity", activity),
                ("scalar_us", s.median_s() * 1e6),
                ("packed_us", p.median_s() * 1e6),
                ("speedup", speedup),
            ],
        );
    }

    // FC layer: bit-plane popcount kernel vs per-spike column adds, forced
    // through the cutover knob on two instances of one weight matrix.
    let fc_in = if quick { 1024 } else { 2304 };
    let fc_out = 64;
    let mut wrng = Rng::new(19);
    let fw: Vec<Vec<i64>> = (0..fc_out)
        .map(|_| (0..fc_in).map(|_| wrng.range_i64(lo, hi)).collect())
        .collect();
    let mut fc_packed = EventFcLayer::new(fw.clone(), res, 60);
    fc_packed.set_packed_cutover(0);
    let mut fc_scalar = EventFcLayer::new(fw, res, 60);
    fc_scalar.set_packed_cutover(usize::MAX);
    for activity in [0.1f64, 0.25] {
        let mut rng = Rng::new(29);
        let frames: Vec<SpikeList> = (0..steps)
            .map(|_| {
                let bits: Vec<bool> = (0..fc_in).map(|_| rng.chance(activity)).collect();
                SpikeList::from_dense(&bits)
            })
            .collect();
        fc_packed.reset();
        fc_scalar.reset();
        for f in &frames {
            assert_eq!(fc_packed.step(f), fc_scalar.step(f));
        }
        fc_packed.reset();
        let p = b.report(&format!("fc bit-plane x{steps} @ {activity}"), || {
            let mut spikes = 0usize;
            for f in &frames {
                fc_packed.step_into(f, &mut out);
                spikes += out.count();
            }
            spikes
        });
        fc_scalar.reset();
        let s = b.report(&format!("fc column-add x{steps} @ {activity}"), || {
            let mut spikes = 0usize;
            for f in &frames {
                fc_scalar.step_into(f, &mut out);
                spikes += out.count();
            }
            spikes
        });
        let speedup = s.median_s() / p.median_s();
        println!("    -> packed fc speedup {speedup:.2}x @ {activity} activity");
        emit_json(
            "packed_step_fc",
            &[
                ("activity", activity),
                ("scalar_us", s.median_s() * 1e6),
                ("packed_us", p.median_s() * 1e6),
                ("speedup", speedup),
            ],
        );
    }
}
