//! Perf-tracking bench: the L3 hot paths, measured the same way before
//! and after each optimization (EXPERIMENTS.md §Perf).
//!
//! Hot paths, in order of end-to-end weight:
//!   1. `CimMacro::cim_accumulate` — the bit-level simulator inner loop
//!      (dominates `flexspim simulate`, Fig. 7a regeneration, and all
//!      macro-level studies).
//!   2. `CimMacro::cim_fire` — comparison + conditional subtract pass.
//!   3. `Mapper::map` — the HS-opt search (dominates dataflow sweeps).
//!   4. `SystemEnergyModel::evaluate` — the system extrapolation kernel.
//!   5. Event generation + encoding — the data path feeding inference.
//!
//! ```sh
//! cargo bench --bench perf_hotpath
//! ```

use flexspim::cim::{CimMacro, MacroConfig};
use flexspim::coordinator::engine::SampleBuffers;
use flexspim::dataflow::{Mapper, Policy};
use flexspim::deploy::DeploymentSpec;
use flexspim::energy::SystemEnergyModel;
use flexspim::events::{encode_frames, GestureClass, GestureGenerator};
use flexspim::snn::network::scnn_dvs_gesture;
use flexspim::snn::Resolution;
use flexspim::util::bench::{emit_json, section, Bench};
use flexspim::util::rng::Rng;

fn main() {
    let b = Bench::default();

    section("1+2. CIM macro simulator");
    for (w, p, n_c, neurons, label) in [
        (8u32, 16u32, 1u32, 256usize, "8b/16b serial x256"),
        (8, 16, 4, 64, "8b/16b 4x4 x64"),
        (4, 9, 3, 85, "4b/9b 3-col x85"),
        (16, 32, 8, 32, "16b/32b 8-col x32"),
    ] {
        let cfg = MacroConfig::flexspim(w, p, n_c, 1, neurons);
        let mut mac = CimMacro::new(cfg).unwrap();
        let mut rng = Rng::new(7);
        for n in 0..neurons {
            mac.load_weight(
                n,
                0,
                rng.range_i64(
                    flexspim::snn::quant::min_val(w),
                    flexspim::snn::quant::max_val(w),
                ),
            );
        }
        let m = b.report(&format!("accumulate {label}"), || {
            mac.cim_accumulate(0, None);
        });
        println!(
            "    -> {:.1} ns/SOP, {:.1} ns/bit-op",
            m.median_s() * 1e9 / neurons as f64,
            m.median_s() * 1e9 / (neurons as f64 * p as f64)
        );
        b.report(&format!("fire       {label}"), || {
            mac.cim_fire(50);
        });
    }

    section("3. dataflow mapping search");
    let net = scnn_dvs_gesture();
    for macros in [2usize, 16] {
        let mapper = Mapper::flexspim(macros);
        b.report(&format!("HS-opt search @ {macros} macros"), || {
            mapper.map(&net, Policy::HsOpt).used_bits
        });
    }

    section("4. system energy evaluation");
    let mapping = Mapper::flexspim(16).map(&net, Policy::HsOpt);
    let sys = SystemEnergyModel::flexspim(16);
    b.report("evaluate full net @ 95 % sparsity", || {
        sys.evaluate(&net, &mapping, 0.95, None).total_pj()
    });
    b.report("sop_pj best-shape search 8b/16b", || {
        sys.sop_pj(8, 16, None)
    });

    section("5. event generation + encoding");
    let gen = GestureGenerator::default_48();
    let mut rng = Rng::new(11);
    b.report("generate gesture sample", || {
        gen.sample(GestureClass::ArmRoll, &mut rng).events.len()
    });
    let stream = gen.sample(GestureClass::ArmRoll, &mut Rng::new(5));
    b.report("encode 16 frames", || encode_frames(&stream, 16).len());

    // The CI `telemetry-overhead` smoke step gates on the emitted
    // overhead_pct (scripts/check_overhead.sh): instrumentation at its
    // default sampling must stay within 5 % of the uninstrumented path.
    section("6. telemetry overhead on the window hot path");
    let dep = DeploymentSpec::builder("telemetry-overhead")
        .timesteps(16)
        .conv("C1", 2, 4, 3, 4, 1, 48, 48, Resolution::new(4, 9))
        .fc("F1", 4 * 12 * 12, 10, Resolution::new(5, 10))
        .macros(2)
        .native_backend(7)
        .build()
        .unwrap()
        .deploy()
        .unwrap();
    let plan = dep.plan().clone();
    let mut backend = dep.backend().unwrap();
    let frames = encode_frames(&stream, 16);
    let mut bufs = SampleBuffers::default();
    let mut rate = vec![0i64; 10];
    let off = b.report("run_frames x16, telemetry off", || {
        rate.iter_mut().for_each(|r| *r = 0);
        plan.run_frames(backend.as_mut(), &mut bufs, &frames, &mut rate)
            .unwrap()
            .sops
    });
    flexspim::telemetry::set_enabled(true);
    flexspim::telemetry::trace::set_tracing(true, 64);
    let on = b.report("run_frames x16, telemetry on (sample 64)", || {
        rate.iter_mut().for_each(|r| *r = 0);
        plan.run_frames(backend.as_mut(), &mut bufs, &frames, &mut rate)
            .unwrap()
            .sops
    });
    flexspim::telemetry::trace::set_tracing(false, 64);
    let overhead_pct = (on.median_s() / off.median_s() - 1.0) * 100.0;
    println!("    -> telemetry overhead {overhead_pct:.2} % (median over median)");
    emit_json(
        "telemetry_overhead",
        &[
            ("off_us", off.median_s() * 1e6),
            ("on_us", on.median_s() * 1e6),
            ("overhead_pct", overhead_pct),
        ],
    );
}
