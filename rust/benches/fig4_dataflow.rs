//! Bench: regenerate Fig. 4 (layer footprints + hybrid-stationarity gain)
//! and time the mapping search.
//!
//! ```sh
//! cargo bench --bench fig4_dataflow
//! ```

use flexspim::dataflow::{Mapper, Policy};
use flexspim::figures::fig4;
use flexspim::snn::network::scnn_dvs_gesture;
use flexspim::util::bench::{section, Bench};

fn main() {
    section("Fig. 4 — reproduction output");
    let f = fig4::run();
    println!("{}", fig4::render(&f));

    section("Fig. 4 — mapping-search timing");
    let net = scnn_dvs_gesture();
    let b = Bench::default();
    for macros in [1usize, 2, 16] {
        let mapper = Mapper::flexspim(macros);
        for policy in [Policy::WsOnly, Policy::HsMin, Policy::HsOpt] {
            b.report(&format!("map {policy} @ {macros} macros"), || {
                mapper.map(&net, policy).avoided_traffic_bits(&net)
            });
        }
    }

    section("Fig. 4 — scaling with macro count (gain vs WS-only)");
    for macros in [1usize, 2, 4, 8, 16, 32] {
        let mapper = Mapper::flexspim(macros);
        let ws = mapper.map(&net, Policy::WsOnly).avoided_traffic_bits(&net);
        let hs = mapper.map(&net, Policy::HsOpt).avoided_traffic_bits(&net);
        println!(
            "{macros:>3} macros: WS-only {ws:>9}  HS-opt {hs:>9}  gain {:+.1} %",
            100.0 * (hs as f64 / ws.max(1) as f64 - 1.0)
        );
    }
}
