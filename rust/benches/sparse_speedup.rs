//! Event-driven speedup: steps/sec of the sparse `NativeScnn` engine vs
//! the dense seed path, swept over input spike activity from 1 % to
//! fully dense (100 %).
//!
//! DVS workloads run at a few percent activity — the regime the paper's
//! event-based execution exploits — so the acceptance bar is a ≥5×
//! native-backend speedup over the dense reference at ≤5 % activity.
//! Bit-identity between the two paths is asserted *while* measuring (the
//! per-layer spike counts of every timestep must match), so the speedup
//! can never come from computing something different.
//!
//! ```sh
//! cargo bench --bench sparse_speedup          # full sweep
//! BENCH_QUICK=1 cargo bench --bench sparse_speedup   # CI smoke
//! ```
//!
//! One `BENCH_JSON {...}` line per activity point records dense and
//! sparse steps/sec plus the speedup for the cross-PR trajectory.

use std::time::Instant;

use flexspim::runtime::{NativeScnn, StepBackend};
use flexspim::snn::events::SpikeList;
use flexspim::snn::{LayerSpec, Network, Resolution};
use flexspim::util::bench::{emit_json, quick_mode, section};
use flexspim::util::rng::Rng;

const SEED: u64 = 42;

/// Conv-heavy mid-size SCNN over the 48×48 substrate — the shape class
/// where dense stepping pays `out_ch × oh × ow × in_ch × k²` per timestep
/// regardless of activity.
fn bench_net() -> Network {
    let r = Resolution::new(4, 9);
    Network::new(
        "sparse-bench",
        vec![
            LayerSpec::conv("C1", 2, 8, 3, 1, 1, 48, 48, r),
            LayerSpec::conv("C2", 8, 16, 3, 2, 1, 48, 48, Resolution::new(5, 10)),
            LayerSpec::conv("C3", 16, 16, 3, 1, 1, 24, 24, Resolution::new(5, 10)),
            LayerSpec::fc("F1", 16 * 24 * 24, 64, r),
            LayerSpec::fc("F2", 64, 10, Resolution::new(5, 10)),
        ],
        8,
    )
}

/// `frames` random spike lists at the given activity over the net's input.
fn frames_at(net: &Network, activity: f64, frames: usize, seed: u64) -> Vec<SpikeList> {
    let (c, h, w) = net.layers[0].in_shape();
    let dim = c * h * w;
    let mut rng = Rng::new(seed);
    (0..frames)
        .map(|_| {
            let bits: Vec<bool> = (0..dim).map(|_| rng.chance(activity)).collect();
            SpikeList::from_dense(&bits)
        })
        .collect()
}

/// Steps/sec of `backend` over `frames`, best of `reps` passes; returns
/// the per-layer counts of the final pass for the identity cross-check.
fn measure(
    backend: &mut NativeScnn,
    frames: &[SpikeList],
    reps: usize,
) -> (f64, Vec<Vec<i32>>) {
    let mut best = 0.0f64;
    let mut counts = Vec::new();
    for _ in 0..reps {
        backend.reset();
        counts.clear();
        let t0 = Instant::now();
        for f in frames {
            counts.push(backend.step(f).expect("bench step").counts);
        }
        let dt = t0.elapsed().as_secs_f64();
        best = best.max(frames.len() as f64 / dt.max(1e-12));
    }
    (best, counts)
}

fn main() {
    let quick = quick_mode();
    let frames_n = if quick { 8 } else { 24 };
    let reps = if quick { 1 } else { 3 };
    // 1.0 is the saturation point: the packed word-parallel path must not
    // regress below the dense reference even with every input bit set.
    let activities: &[f64] = if quick {
        &[0.01, 0.05, 0.2, 1.0]
    } else {
        &[0.01, 0.02, 0.05, 0.1, 0.2, 0.5, 1.0]
    };
    let net = bench_net();
    section(&format!(
        "sparse speedup — {} layers, {frames_n} frames/pass, activity sweep",
        net.layers.len()
    ));

    let mut sparse = NativeScnn::new(net.clone(), SEED);
    let mut dense = NativeScnn::new_dense_reference(net.clone(), SEED);
    let mut speedup_at_low = 0.0f64;
    for &activity in activities {
        let frames = frames_at(&net, activity, frames_n, 7u64 ^ ((activity * 1e4) as u64));
        let (sparse_sps, sparse_counts) = measure(&mut sparse, &frames, reps);
        let (dense_sps, dense_counts) = measure(&mut dense, &frames, reps);
        assert_eq!(
            sparse_counts, dense_counts,
            "sparse and dense paths must stay bit-identical while measuring"
        );
        let speedup = sparse_sps / dense_sps.max(1e-12);
        if activity <= 0.05 {
            speedup_at_low = speedup_at_low.max(speedup);
        }
        println!(
            "activity {:5.1} %:  dense {dense_sps:9.2} steps/s   sparse {sparse_sps:9.2} steps/s   speedup {speedup:6.2}x",
            100.0 * activity
        );
        emit_json(
            "sparse_speedup",
            &[
                ("activity", activity),
                ("dense_steps_per_sec", dense_sps),
                ("sparse_steps_per_sec", sparse_sps),
                ("speedup", speedup),
            ],
        );
    }
    println!(
        "\nacceptance: >= 5x sparse-over-dense at <= 5 % activity (best measured: {speedup_at_low:.2}x)"
    );
}
