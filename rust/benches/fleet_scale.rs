//! Scale-out trajectory of the fleet serving tier.
//!
//! Each step boots a fleet at half its target size and replays the same
//! per-node session load, scaled to the target, through
//! [`flexspim::fleet::Fleet::drive_open_loop`]. The watermark autoscaler
//! grows the fleet to the target mid-drive, so every step exercises the
//! full rebalancing path — standby activation, broadcast weight push,
//! consistent-hash rebalance, and priced vmem checkpoint migrations —
//! not just steady-state routing. Reported per step: sessions per node,
//! migration traffic, and modeled energy per session (interconnect
//! included) versus fleet size.
//!
//! A follow-on experiment compares the two placement modes at a fixed
//! size: replicated (weight broadcast at join, no boundary traffic)
//! vs. layer-sharded (cheaper unicast re-homing, but every executed
//! window pays modeled shard-boundary spike planes on the link).
//!
//! ```sh
//! cargo bench --bench fleet_scale            # 1/2/4/8-node fleets
//! BENCH_QUICK=1 cargo bench --bench fleet_scale   # CI smoke (1/2 nodes)
//! ```
//!
//! One `BENCH_JSON {...}` line per step for the cross-PR trajectory
//! (`BENCH_fleet.json`; capture with `scripts/capture_bench.sh`).

use flexspim::dataflow::Policy;
use flexspim::deploy::{DeploymentSpec, FleetSpec, Placement};
use flexspim::fleet::Fleet;
use flexspim::serve::{gesture_traffic, ArrivalProcess, LoadConfig};
use flexspim::snn::{LayerSpec, Network, Resolution};
use flexspim::util::bench::{emit_json, quick_mode, section};

const SEED: u64 = 42;
const MACROS: usize = 16;
/// Intra-session compression: the 100-ms gesture plays out in 10 ms.
const TIME_SCALE: f64 = 10.0;
const CHUNK: usize = 64;
/// Offered session arrivals per target node — comfortably inside one
/// worker's capacity, so the sweep measures scale-out, not saturation.
const RATE_PER_NODE: f64 = 40.0;

/// Same mid-size SCNN as the serve benches, for comparable numbers.
fn bench_net() -> Network {
    let r = Resolution::new(4, 9);
    Network::new(
        "fleet-scale",
        vec![
            LayerSpec::conv("C1", 2, 8, 3, 4, 1, 48, 48, r),
            LayerSpec::fc("F1", 8 * 12 * 12, 64, r),
            LayerSpec::fc("F2", 64, 10, Resolution::new(5, 10)),
        ],
        16,
    )
}

/// Materialize a fresh fleet through the deployment API (the same path
/// `flexspim fleet --config` takes). One worker per node keeps the
/// per-node capacity fixed, so goodput growth is attributable to nodes.
fn fleet_for(spec: FleetSpec) -> Fleet {
    DeploymentSpec::builder("fleet-scale")
        .network(&bench_net())
        .macros(MACROS)
        .policy(Policy::HsOpt)
        .native_backend(SEED)
        .workers(1)
        .queue_capacity(256)
        .fleet(spec)
        .build()
        .expect("bench spec is valid")
        .deploy()
        .expect("bench spec deploys")
        .fleet()
        .expect("fleet materializes")
}

fn main() {
    let quick = quick_mode();
    let targets: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4, 8] };
    let per_node_sessions = if quick { 4 } else { 8 };

    section("scale-out sweep — boot at half size, autoscale to target under load");
    let mut migrations_total = 0u64;
    let mut four_node_row_live = 0usize;
    for &target in targets {
        let boot = (target / 2).max(1);
        let spec = FleetSpec {
            nodes: boot,
            max_nodes: if target > boot { target } else { 0 },
            // Below per-node offered load, so growth to the target is
            // guaranteed mid-drive (not only at the end of the ramp).
            scale_high_sessions: 6,
            ..FleetSpec::default()
        };
        let mut fleet = fleet_for(spec);
        let sessions = per_node_sessions * target;
        let traffic = gesture_traffic(sessions, 7, 0);
        let cfg = LoadConfig {
            arrivals: ArrivalProcess::Poisson { rate_per_sec: RATE_PER_NODE * target as f64 },
            time_scale: TIME_SCALE,
            chunk: CHUNK,
            seed: 0xF1EE7 + target as u64,
        };
        let r = fleet.drive_open_loop(&traffic, &cfg).expect("open-loop drive");
        assert_eq!(
            r.fleet.finished_sessions, sessions as u64,
            "the fleet degrades sessions under load, never loses them"
        );
        assert_eq!(
            r.fleet.nodes_live, target,
            "the watermark autoscaler must reach the target size"
        );
        migrations_total += r.fleet.migrations;
        if target == 4 {
            four_node_row_live = r.fleet.nodes_live;
        }
        println!(
            "{target} nodes (boot {boot}): {:5.1} sessions/node  goodput {:8.2} w/s  \
             {} migrations ({} bits)  link {:.1} nJ  {:.1} nJ/session",
            r.fleet.sessions_per_node(),
            r.goodput_windows_per_sec,
            r.fleet.migrations,
            r.fleet.vmem_move_bits,
            r.fleet.link_energy_pj / 1e3,
            r.fleet.energy_per_session_pj() / 1e3,
        );
        print!("{}", r.fleet.report());
        emit_json(
            "fleet_scale",
            &[
                ("nodes", target as f64),
                ("boot_nodes", boot as f64),
                ("live_nodes", r.fleet.nodes_live as f64),
                ("sessions", r.fleet.sessions as f64),
                ("finished", r.fleet.finished_sessions as f64),
                ("sessions_per_node", r.fleet.sessions_per_node()),
                ("windows_done", r.fleet.windows_done as f64),
                ("windows_shed", r.fleet.windows_shed as f64),
                ("migrations", r.fleet.migrations as f64),
                ("migration_bits", r.fleet.vmem_move_bits as f64),
                ("weight_push_bits", r.fleet.weight_push_bits as f64),
                ("link_bits", r.fleet.link_bits as f64),
                ("link_energy_nj", r.fleet.link_energy_pj / 1e3),
                ("energy_per_session_nj", r.fleet.energy_per_session_pj() / 1e3),
                ("offered_wps", r.offered_windows_per_sec),
                ("goodput_wps", r.goodput_windows_per_sec),
                ("p99_ms", r.fleet.latency.p99() * 1e3),
                ("max_lag_s", r.max_lag_s),
                ("drive_wall_s", r.drive_wall_s),
            ],
        );
    }
    if !quick {
        assert_eq!(four_node_row_live, 4, "the sweep must include a live 4-node fleet");
        assert!(
            migrations_total > 0,
            "autoscale joins must rebalance at least one live session"
        );
        println!("\nacceptance: 4-node fleet served, autoscale migrations priced on the link");
    }

    // Placement comparison at a fixed size: same traffic, same nodes —
    // only the weight-placement policy (and thus the interconnect bill)
    // differs. Execution stays replicated in simulation; the sharded
    // ledger is the traffic model.
    let nodes = if quick { 2 } else { 4 };
    section(&format!("placement at {nodes} nodes — replicated vs. layer-sharded interconnect"));
    let mut boundary = [0u64; 2];
    for (idx, placement) in [Placement::Replicated, Placement::LayerSharded].iter().enumerate() {
        let spec = FleetSpec { nodes, placement: *placement, ..FleetSpec::default() };
        let mut fleet = fleet_for(spec);
        let traffic = gesture_traffic(per_node_sessions * nodes, 7, 0);
        let cfg = LoadConfig {
            arrivals: ArrivalProcess::Poisson { rate_per_sec: RATE_PER_NODE * nodes as f64 },
            time_scale: TIME_SCALE,
            chunk: CHUNK,
            seed: 0x91ACE,
        };
        let r = fleet.drive_open_loop(&traffic, &cfg).expect("open-loop drive");
        boundary[idx] = r.fleet.boundary_bits;
        println!(
            "{:13}: {:10} link bits ({:10} weight-push, {:10} boundary) = {:8.1} nJ",
            format!("{placement:?}"),
            r.fleet.link_bits,
            r.fleet.weight_push_bits,
            r.fleet.boundary_bits,
            r.fleet.link_energy_pj / 1e3,
        );
        emit_json(
            "fleet_scale_placement",
            &[
                ("sharded", idx as f64),
                ("nodes", nodes as f64),
                ("link_bits", r.fleet.link_bits as f64),
                ("weight_push_bits", r.fleet.weight_push_bits as f64),
                ("boundary_bits", r.fleet.boundary_bits as f64),
                ("migration_bits", r.fleet.vmem_move_bits as f64),
                ("link_energy_nj", r.fleet.link_energy_pj / 1e3),
                ("windows_done", r.fleet.windows_done as f64),
                ("finished", r.fleet.finished_sessions as f64),
            ],
        );
    }
    assert_eq!(boundary[0], 0, "replicated placement pays no shard-boundary traffic");
    assert!(boundary[1] > 0, "layer sharding must price boundary spike planes");
    println!("\nacceptance: sharded boundary traffic priced, absent under replication");
}
