//! Open-loop saturation sweep of the streaming serve tier.
//!
//! Unlike `serve_streaming` (closed-loop: the driver waits for the
//! service, so offered load self-throttles), this bench commits to an
//! arrival schedule and holds it against the wall clock via
//! [`flexspim::serve::drive_open_loop`]. A stepped ramp of offered load —
//! multiples of the calibrated single-worker capacity — at several pool
//! sizes exposes the three regimes:
//!
//! * **linear** — goodput tracks offered load, nothing shed;
//! * **knee**   — goodput falls behind, queues absorb the excess;
//! * **shed**   — the admission bound trips and windows drop.
//!
//! Two follow-on experiments at the knee: Poisson vs. bursty arrivals at
//! the same mean rate (burstiness alone moves the shed rate), and the SLO
//! autoscaler vs. a fixed single worker (growth at the knee pulls p99
//! back down).
//!
//! ```sh
//! cargo bench --bench serve_saturation          # full ramp (48 sessions/step)
//! BENCH_QUICK=1 cargo bench --bench serve_saturation   # CI smoke (12)
//! ```
//!
//! One `BENCH_JSON {...}` line per ramp step for the cross-PR trajectory
//! (`BENCH_saturation.json`; capture with `scripts/capture_bench.sh`).

use flexspim::dataflow::Policy;
use flexspim::deploy::{AutoscaleSpec, DeploymentSpec};
use flexspim::serve::{
    drive_open_loop, gesture_traffic, ArrivalProcess, LoadConfig, LoadReport, SessionTraffic,
    StreamingService,
};
use flexspim::snn::{LayerSpec, Network, Resolution};
use flexspim::util::bench::{emit_json, quick_mode, section};

const SEED: u64 = 42;
const MACROS: usize = 16;
/// Intra-session compression: the 100-ms gesture plays out in 10 ms.
const TIME_SCALE: f64 = 10.0;
const CHUNK: usize = 64;

/// Same mid-size SCNN as `serve_streaming`, for comparable numbers.
fn bench_net() -> Network {
    let r = Resolution::new(4, 9);
    Network::new(
        "serve-saturation",
        vec![
            LayerSpec::conv("C1", 2, 8, 3, 4, 1, 48, 48, r),
            LayerSpec::fc("F1", 8 * 12 * 12, 64, r),
            LayerSpec::fc("F2", 64, 10, Resolution::new(5, 10)),
        ],
        16,
    )
}

/// Materialize a fresh service through the deployment API (the same path
/// `flexspim serve --config` takes).
fn service_for(
    workers: usize,
    queue_capacity: usize,
    autoscale: Option<AutoscaleSpec>,
    telemetry: bool,
) -> StreamingService {
    let mut builder = DeploymentSpec::builder("serve-saturation")
        .network(&bench_net())
        .macros(MACROS)
        .policy(Policy::HsOpt)
        .native_backend(SEED)
        .workers(workers)
        .queue_capacity(queue_capacity)
        .telemetry_enabled(telemetry);
    if let Some(spec) = autoscale {
        builder = builder.autoscale(spec);
    }
    builder
        .build()
        .expect("bench spec is valid")
        .deploy()
        .expect("bench spec deploys")
        .service()
        .expect("service materializes")
}

fn drive(
    svc: &StreamingService,
    traffic: &[SessionTraffic],
    arrivals: ArrivalProcess,
    seed: u64,
) -> LoadReport {
    let cfg = LoadConfig { arrivals, time_scale: TIME_SCALE, chunk: CHUNK, seed };
    drive_open_loop(svc, traffic, &cfg).expect("open-loop drive")
}

/// Regime classification for one ramp step.
fn regime(r: &LoadReport) -> &'static str {
    if r.serve.shed_rate() > 0.01 {
        "shed"
    } else if r.goodput_windows_per_sec >= 0.9 * r.offered_windows_per_sec {
        "linear"
    } else {
        "knee"
    }
}

fn main() {
    let quick = quick_mode();
    let sessions = if quick { 12 } else { 48 };
    let queue_capacity = if quick { 32 } else { 128 };
    let multipliers: &[f64] = if quick { &[0.25, 1.0, 4.0] } else { &[0.25, 0.5, 1.0, 2.0, 4.0] };
    let worker_counts: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4] };
    let traffic = gesture_traffic(sessions, 7, 0);

    // Calibrate single-worker capacity with a closed-loop run: its
    // self-paced equilibrium *is* the sustainable session rate.
    section(&format!("calibration — closed-loop, 1 worker, {sessions} sessions"));
    let cal = service_for(1, queue_capacity, None, false)
        .serve(&traffic, CHUNK)
        .expect("calibration run");
    assert_eq!(cal.finished_sessions, sessions as u64);
    let cap_sessions_per_sec = cal.sessions_per_sec();
    println!(
        "1 worker sustains {cap_sessions_per_sec:7.2} sessions/s  ({:8.2} windows/s)",
        cal.windows_per_sec()
    );

    section("open-loop ramp — offered load × calibrated per-worker capacity");
    let mut top_mult_shed_1w = 0u64;
    for &workers in worker_counts {
        for &mult in multipliers {
            let rate = mult * cap_sessions_per_sec * workers as f64;
            let svc = service_for(workers, queue_capacity, None, false);
            let r = drive(
                &svc,
                &traffic,
                ArrivalProcess::Poisson { rate_per_sec: rate },
                0x5A7 + workers as u64,
            );
            assert_eq!(
                r.serve.finished_sessions, sessions as u64,
                "overload degrades sessions, never loses them"
            );
            if workers == 1 && mult == multipliers[multipliers.len() - 1] {
                top_mult_shed_1w = r.serve.windows_shed;
            }
            println!(
                "{workers}w x{mult:4.2}: offered {:8.2} w/s  goodput {:8.2} w/s  {}  shed {:5.2} %  lag {:6.1} ms  [{}]",
                r.offered_windows_per_sec,
                r.goodput_windows_per_sec,
                r.serve.latency.line(),
                100.0 * r.serve.shed_rate(),
                1e3 * r.max_lag_s,
                regime(&r),
            );
            emit_json(
                "serve_saturation",
                &[
                    ("workers", workers as f64),
                    ("burst", 1.0),
                    ("mult", mult),
                    ("offered_wps", r.offered_windows_per_sec),
                    ("goodput_wps", r.goodput_windows_per_sec),
                    ("p50_ms", r.serve.latency.p50() * 1e3),
                    ("p95_ms", r.serve.latency.p95() * 1e3),
                    ("p99_ms", r.serve.latency.p99() * 1e3),
                    ("shed_rate", r.serve.shed_rate()),
                    ("events_late", r.serve.events_late as f64),
                    ("events_overflow", r.serve.events_overflow as f64),
                    ("events_discarded", r.serve.events_flush_discarded as f64),
                    ("max_lag_s", r.max_lag_s),
                ],
            );
        }
    }
    assert!(
        top_mult_shed_1w > 0,
        "the top of the ramp must reach the shedding regime on one worker"
    );
    println!("\nacceptance: 1-worker ramp reaches shedding at the top multiplier");

    // Burstiness at the knee: same mean rate, arrivals concentrated into
    // groups of 4 — admission sees the load as spikes.
    section("burstiness at the knee — Poisson vs. 4-bursts at 1× capacity, 1 worker");
    for burst in [1usize, 4] {
        let rate = cap_sessions_per_sec;
        let arrivals = if burst == 1 {
            ArrivalProcess::Poisson { rate_per_sec: rate }
        } else {
            ArrivalProcess::Bursty { rate_per_sec: rate, burst }
        };
        let svc = service_for(1, queue_capacity, None, false);
        let r = drive(&svc, &traffic, arrivals, 0xB00);
        println!(
            "burst {burst}: goodput {:8.2} w/s  {}  shed {:5.2} %",
            r.goodput_windows_per_sec,
            r.serve.latency.line(),
            100.0 * r.serve.shed_rate(),
        );
        emit_json(
            "serve_saturation_burst",
            &[
                ("burst", burst as f64),
                ("offered_wps", r.offered_windows_per_sec),
                ("goodput_wps", r.goodput_windows_per_sec),
                ("p99_ms", r.serve.latency.p99() * 1e3),
                ("shed_rate", r.serve.shed_rate()),
            ],
        );
    }

    // Autoscaler at the knee: start at 1 worker under 1.5× single-worker
    // load; the SLO breach must grow the pool and pull p99 back down
    // versus the pinned single worker.
    section("autoscaler at the knee — fixed 1 worker vs. SLO-driven growth to 4");
    let rate = 1.5 * cap_sessions_per_sec;
    let fixed = {
        let svc = service_for(1, queue_capacity, None, false);
        drive(&svc, &traffic, ArrivalProcess::Poisson { rate_per_sec: rate }, 0xA5C)
    };
    // The autoscaled run doubles as the flight-recorder exercise: with
    // telemetry on, every decide tick and scale transition lands in the
    // ring, so the decision trail printed below is the same evidence
    // `flexspim serve --dump-telemetry` would show.
    let auto_svc = {
        let spec = AutoscaleSpec {
            enabled: true,
            min_workers: 1,
            max_workers: 4,
            slo_p99_ms: 10.0,
            interval_ms: 5,
            queue_high: 4,
            hysteresis_ticks: 3,
        };
        service_for(1, queue_capacity, Some(spec), true)
    };
    let auto = drive(&auto_svc, &traffic, ArrivalProcess::Poisson { rate_per_sec: rate }, 0xA5C);
    assert_eq!(auto.serve.finished_sessions, sessions as u64);
    assert!(
        auto.serve.scale_ups > 0 && auto.serve.workers_peak > 1,
        "a sustained 1.5x overload must trip the autoscaler"
    );
    // Decide ticks keep arriving until shutdown, so the bounded ring is
    // guaranteed to retain recent ones; scale-ups fire early and may have
    // been displaced by later events — report, don't assert.
    let rec = auto_svc.recorder();
    let decisions = rec.events_of_kind("autoscale-decision").len();
    assert!(decisions > 0, "flight recorder must retain the autoscaler's decision trail");
    println!(
        "flight recorder: {decisions} decide ticks retained, {} scale-ups retained, \
         {} events total ({} dropped by the ring)",
        rec.events_of_kind("scale-up").len(),
        rec.recorded(),
        rec.dropped(),
    );
    for (name, r) in [("fixed 1w", &fixed), ("autoscale", &auto)] {
        println!(
            "{name}: peak {} workers ({} ups, {} downs)  goodput {:8.2} w/s  {}  shed {:5.2} %",
            r.serve.workers_peak,
            r.serve.scale_ups,
            r.serve.scale_downs,
            r.goodput_windows_per_sec,
            r.serve.latency.line(),
            100.0 * r.serve.shed_rate(),
        );
    }
    emit_json(
        "serve_saturation_autoscale",
        &[
            ("fixed_p99_ms", fixed.serve.latency.p99() * 1e3),
            ("auto_p99_ms", auto.serve.latency.p99() * 1e3),
            ("auto_peak_workers", auto.serve.workers_peak as f64),
            ("auto_scale_ups", auto.serve.scale_ups as f64),
            ("auto_scale_downs", auto.serve.scale_downs as f64),
            ("fixed_goodput_wps", fixed.goodput_windows_per_sec),
            ("auto_goodput_wps", auto.goodput_windows_per_sec),
        ],
    );
    if !quick {
        // Timing-sensitive, so asserted only in the full run: with 4×
        // the compute, the grown pool must beat the pinned worker's p99.
        assert!(
            auto.serve.latency.p99() < fixed.serve.latency.p99(),
            "autoscaler must reduce p99 at the knee (auto {:.1} ms vs fixed {:.1} ms)",
            auto.serve.latency.p99() * 1e3,
            fixed.serve.latency.p99() * 1e3,
        );
    }
    println!("\nacceptance: autoscaler grows at the knee and reduces p99 vs the fixed pool");
}
