//! Streaming serve throughput and latency: hundreds of synthetic gesture
//! sessions with arrival jitter driven through the serve tier at 1, 2, 4,
//! and 8 workers.
//!
//! Reported per worker count: p50/p95/p99 per-window latency (admission →
//! completion), sessions/sec, windows/sec, and the shed rate — which must
//! stay 0 under this nominal load (the acceptance bar). Session results
//! are additionally cross-checked for worker-count invariance while
//! measuring: state travels by snapshot, so the pool size must never
//! change what is computed.
//!
//! ```sh
//! cargo bench --bench serve_streaming          # full run (200 sessions)
//! BENCH_QUICK=1 cargo bench --bench serve_streaming   # CI smoke (24)
//! ```
//!
//! One `BENCH_JSON {...}` line per worker count is emitted for the
//! cross-PR bench trajectory (`BENCH_*.json`), plus companion sections:
//! the early-exit trade-off, the telemetry exporters, and an adaptive-
//! precision Pareto sweep (fixed fig6 tiers vs the serve-time controller)
//! recorded as `serve_precision_pareto` rows in `BENCH_streaming.json`.

use flexspim::dataflow::Policy;
use flexspim::deploy::{DeploymentSpec, PrecisionSpec};
use flexspim::serve::{gesture_traffic, tiers_for, StreamingService};
use flexspim::snn::{LayerSpec, Network, Resolution};
use flexspim::util::bench::{emit_json, quick_mode, section};

const SEED: u64 = 42;
const MACROS: usize = 16;
const JITTER_US: u64 = 8_000;

/// Materialize the service from a deployment spec — the same entry point
/// `flexspim serve --config` uses, so the bench measures the deployed
/// configuration, not a bespoke wiring.
fn service_for(workers: usize, early_exit: Option<f64>) -> StreamingService {
    let mut builder = DeploymentSpec::builder("serve-bench")
        .network(&bench_net())
        .macros(MACROS)
        .policy(Policy::HsOpt)
        .native_backend(SEED)
        .workers(workers);
    if let Some(margin) = early_exit {
        builder = builder.early_exit(margin, 1);
    }
    builder
        .build()
        .expect("bench spec is valid")
        .deploy()
        .expect("bench spec deploys")
        .service()
        .expect("service materializes")
}

/// Mid-size SCNN over the 48×48 substrate with 16 timesteps (4 windows of
/// 4 frames per 100-ms session): heavy enough that window execution
/// dominates queue orchestration, light enough for quick turnaround.
fn bench_net() -> Network {
    let r = Resolution::new(4, 9);
    Network::new(
        "serve-bench",
        vec![
            LayerSpec::conv("C1", 2, 8, 3, 4, 1, 48, 48, r),
            LayerSpec::fc("F1", 8 * 12 * 12, 64, r),
            LayerSpec::fc("F2", 64, 10, Resolution::new(5, 10)),
        ],
        16,
    )
}

fn main() {
    let sessions = if quick_mode() { 24 } else { 200 };
    section(&format!(
        "serve streaming — {sessions} synthetic gesture sessions, {JITTER_US} us jitter"
    ));
    let traffic = gesture_traffic(sessions, 7, JITTER_US);

    let mut reference_sops = 0u64;
    for &workers in &[1usize, 2, 4, 8] {
        let svc = service_for(workers, None);
        let report = svc.serve(&traffic, 64).expect("serve run");
        assert_eq!(
            report.finished_sessions, sessions as u64,
            "every session must finish"
        );
        assert_eq!(report.windows_shed, 0, "nominal load must not shed");
        if workers == 1 {
            reference_sops = report.metrics.sops;
        }
        assert_eq!(
            report.metrics.sops, reference_sops,
            "session results must be worker-count invariant"
        );
        println!(
            "{workers} worker(s): {:7.2} sessions/s  {:8.2} windows/s  {}  shed {:.2} %",
            report.sessions_per_sec(),
            report.windows_per_sec(),
            report.latency.line(),
            100.0 * report.shed_rate(),
        );
        emit_json(
            "serve_streaming",
            &[
                ("workers", workers as f64),
                ("sessions", sessions as f64),
                ("sessions_per_sec", report.sessions_per_sec()),
                ("windows_per_sec", report.windows_per_sec()),
                ("p50_ms", report.latency.p50() * 1e3),
                ("p95_ms", report.latency.p95() * 1e3),
                ("p99_ms", report.latency.p99() * 1e3),
                ("shed_rate", report.shed_rate()),
                ("evictions", report.evictions as f64),
            ],
        );
    }
    println!("\nacceptance: shed rate 0 under nominal load at every pool size");

    // Early-exit trade-off: frames saved vs rolling-accuracy delta against
    // the no-exit baseline, at increasing confidence bounds.
    section("early exit — frames saved vs rolling accuracy (4 workers)");
    let baseline = service_for(4, None).serve(&traffic, 64).expect("baseline run");
    let base_acc = baseline.rolling_correct as f64 / baseline.sessions.max(1) as f64;
    let base_frames = baseline.metrics.timesteps;
    for &margin in &[0.5f64, 1.0, 2.0] {
        let svc = service_for(4, Some(margin));
        let report = svc.serve(&traffic, 64).expect("early-exit run");
        assert_eq!(report.finished_sessions, sessions as u64);
        let acc = report.rolling_correct as f64 / report.sessions.max(1) as f64;
        let saved_frac = report.frames_saved as f64 / base_frames.max(1) as f64;
        println!(
            "margin {margin:4.1}:  {:4} exits  {:5} frames saved ({:5.1} %)  accuracy {:5.1} % (delta {:+5.1} pp)",
            report.early_exits,
            report.frames_saved,
            100.0 * saved_frac,
            100.0 * acc,
            100.0 * (acc - base_acc),
        );
        emit_json(
            "serve_early_exit",
            &[
                ("margin", margin),
                ("early_exits", report.early_exits as f64),
                ("frames_saved", report.frames_saved as f64),
                ("frames_saved_frac", saved_frac),
                ("rolling_accuracy", acc),
                ("accuracy_delta", acc - base_acc),
            ],
        );
    }

    // The same exporters `flexspim serve --dump-telemetry` prints,
    // exercised on the bench workload so the serve-path instrumentation
    // stays wired end to end.
    section("telemetry exporters — metrics registry + flight recorder (2 workers)");
    let svc = DeploymentSpec::builder("serve-bench-telemetry")
        .network(&bench_net())
        .macros(MACROS)
        .policy(Policy::HsOpt)
        .native_backend(SEED)
        .workers(2)
        .telemetry_enabled(true)
        .build()
        .expect("telemetry spec is valid")
        .deploy()
        .expect("telemetry spec deploys")
        .service()
        .expect("service materializes");
    let report = svc.serve(&traffic, 64).expect("telemetry run");
    assert_eq!(report.finished_sessions, sessions as u64);
    let snap = svc.metrics().snapshot();
    let admitted = snap.counter_total("flexspim_serve_admitted_total");
    let done = snap.counter_total("flexspim_serve_windows_done_total");
    let shed = snap.counter_total("flexspim_serve_shed_total");
    assert!(admitted > 0, "instrumented run must admit windows");
    assert_eq!(shed, 0, "nominal load must not shed");
    assert_eq!(done, admitted, "every admitted window must commit");
    assert!(
        svc.metrics().prometheus_text().contains("flexspim_serve_windows_done_total"),
        "Prometheus export must carry the serve families"
    );
    println!(
        "registry: {admitted} admitted, {done} done, {shed} shed  |  {}",
        svc.recorder().dump().lines().next().unwrap_or_default()
    );
    emit_json(
        "serve_telemetry",
        &[
            ("admitted", admitted as f64),
            ("windows_done", done as f64),
            ("shed", shed as f64),
            ("queue_wait_samples", snap.histogram_count("flexspim_serve_queue_wait_seconds") as f64),
            ("flight_recorded", svc.recorder().recorded() as f64),
        ],
    );

    // Precision Pareto: every fixed tier of the fig6 grid as its own
    // deployment, then the adaptive controller under a hair-trigger drop
    // policy — the paper's ~90 %-energy resolution headroom recast as a
    // serve-time load-shedding strategy. The adaptive point must land
    // below the full-precision baseline on energy while every session
    // still finishes.
    section("precision Pareto — fixed tiers vs adaptive controller (2 workers)");
    let tiers = tiers_for(&bench_net(), 3);
    let mut rows: Vec<(f64, f64, f64, f64, f64, u64, u64)> = Vec::new();
    let mut base_energy = (0.0f64, 0.0f64); // (total, compute) pj/session at tier 0
    for (tier, res) in tiers.iter().enumerate() {
        let net = bench_net().with_resolutions(
            &res.iter().map(|&(w, p)| Resolution::new(w, p)).collect::<Vec<_>>(),
        );
        let svc = DeploymentSpec::builder("serve-bench-fixed")
            .network(&net)
            .macros(MACROS)
            .policy(Policy::HsOpt)
            .native_backend(SEED)
            .workers(2)
            .build()
            .expect("fixed-tier spec is valid")
            .deploy()
            .expect("fixed-tier spec deploys")
            .service()
            .expect("service materializes");
        let report = svc.serve(&traffic, 64).expect("fixed-tier run");
        assert_eq!(report.finished_sessions, sessions as u64);
        assert_eq!(report.precision_shifts, 0, "fixed tiers must not reconfigure");
        let energy = report.metrics.energy.total_pj() / sessions as f64;
        if tier == 0 {
            base_energy =
                (energy, report.metrics.energy.compute_pj / sessions as f64);
        }
        let acc = report.rolling_correct as f64 / report.sessions.max(1) as f64;
        rows.push((
            tier as f64,
            energy,
            energy / base_energy.0,
            acc,
            report.latency.p99() * 1e3,
            report.windows_done,
            report.precision_shifts,
        ));
    }

    let adaptive = DeploymentSpec::builder("serve-bench-adaptive")
        .network(&bench_net())
        .macros(MACROS)
        .policy(Policy::HsOpt)
        .native_backend(SEED)
        .workers(2)
        .telemetry_enabled(true)
        .precision(PrecisionSpec {
            enabled: true,
            max_delta: 3,
            // Unreachable latency bound: every committed window reads as
            // load, so sessions sink tier by tier — the pure shedding
            // endpoint of the policy space.
            drop_p99_ms: 1e-6,
            queue_high: 1,
            raise_margin: 0.0,
            min_windows: 2,
        })
        .build()
        .expect("adaptive spec is valid")
        .deploy()
        .expect("adaptive spec deploys")
        .service()
        .expect("service materializes");
    let report = adaptive.serve(&traffic, 64).expect("adaptive run");
    assert_eq!(report.finished_sessions, sessions as u64);
    assert!(report.precision_shifts > 0, "the controller must act under load");
    assert!(
        report.tier_windows[1..].iter().sum::<u64>() > 0,
        "windows must execute below full precision"
    );
    let decisions = adaptive.recorder().events_of_kind("precision-decision");
    assert_eq!(
        decisions.len() as u64,
        report.precision_shifts,
        "every controller decision must reach the flight recorder"
    );
    let adaptive_energy = report.metrics.energy.total_pj() / sessions as f64;
    assert!(
        report.metrics.energy.compute_pj / sessions as f64 < base_energy.1,
        "shedding precision must shed compute energy"
    );
    let acc = report.rolling_correct as f64 / report.sessions.max(1) as f64;
    rows.push((
        f64::NAN, // tier: the controller moves across tiers (renders null)
        adaptive_energy,
        adaptive_energy / base_energy.0,
        acc,
        report.latency.p99() * 1e3,
        report.windows_done,
        report.precision_shifts,
    ));

    for (i, &(tier, energy, rel, acc, p99, windows, shifts)) in rows.iter().enumerate() {
        let label = if tier.is_finite() {
            format!("fixed tier {tier:.0}")
        } else {
            "adaptive     ".to_string()
        };
        println!(
            "{label}: {energy:10.1} pJ/session ({:5.1} % of tier 0)  accuracy {:5.1} %  p99 {p99:7.3} ms  {shifts} shifts",
            100.0 * rel,
            100.0 * acc,
        );
        emit_json(
            "serve_precision_pareto",
            &[
                ("adaptive", (i == rows.len() - 1) as u64 as f64),
                ("tier", tier),
                ("energy_pj_per_session", energy),
                ("energy_rel", rel),
                ("accuracy", acc),
                ("p99_ms", p99),
                ("windows_done", windows as f64),
                ("precision_shifts", shifts as f64),
            ],
        );
    }
    println!(
        "\nacceptance: adaptive energy below the full-precision baseline with every session finished"
    );
}
