//! Streaming serve throughput and latency: hundreds of synthetic gesture
//! sessions with arrival jitter driven through the serve tier at 1, 2, 4,
//! and 8 workers.
//!
//! Reported per worker count: p50/p95/p99 per-window latency (admission →
//! completion), sessions/sec, windows/sec, and the shed rate — which must
//! stay 0 under this nominal load (the acceptance bar). Session results
//! are additionally cross-checked for worker-count invariance while
//! measuring: state travels by snapshot, so the pool size must never
//! change what is computed.
//!
//! ```sh
//! cargo bench --bench serve_streaming          # full run (200 sessions)
//! BENCH_QUICK=1 cargo bench --bench serve_streaming   # CI smoke (24)
//! ```
//!
//! One `BENCH_JSON {...}` line per worker count is emitted for the
//! cross-PR bench trajectory (`BENCH_*.json`).

use flexspim::dataflow::Policy;
use flexspim::deploy::DeploymentSpec;
use flexspim::serve::{gesture_traffic, StreamingService};
use flexspim::snn::{LayerSpec, Network, Resolution};
use flexspim::util::bench::{emit_json, quick_mode, section};

const SEED: u64 = 42;
const MACROS: usize = 16;
const JITTER_US: u64 = 8_000;

/// Materialize the service from a deployment spec — the same entry point
/// `flexspim serve --config` uses, so the bench measures the deployed
/// configuration, not a bespoke wiring.
fn service_for(workers: usize, early_exit: Option<f64>) -> StreamingService {
    let mut builder = DeploymentSpec::builder("serve-bench")
        .network(&bench_net())
        .macros(MACROS)
        .policy(Policy::HsOpt)
        .native_backend(SEED)
        .workers(workers);
    if let Some(margin) = early_exit {
        builder = builder.early_exit(margin, 1);
    }
    builder
        .build()
        .expect("bench spec is valid")
        .deploy()
        .expect("bench spec deploys")
        .service()
        .expect("service materializes")
}

/// Mid-size SCNN over the 48×48 substrate with 16 timesteps (4 windows of
/// 4 frames per 100-ms session): heavy enough that window execution
/// dominates queue orchestration, light enough for quick turnaround.
fn bench_net() -> Network {
    let r = Resolution::new(4, 9);
    Network::new(
        "serve-bench",
        vec![
            LayerSpec::conv("C1", 2, 8, 3, 4, 1, 48, 48, r),
            LayerSpec::fc("F1", 8 * 12 * 12, 64, r),
            LayerSpec::fc("F2", 64, 10, Resolution::new(5, 10)),
        ],
        16,
    )
}

fn main() {
    let sessions = if quick_mode() { 24 } else { 200 };
    section(&format!(
        "serve streaming — {sessions} synthetic gesture sessions, {JITTER_US} us jitter"
    ));
    let traffic = gesture_traffic(sessions, 7, JITTER_US);

    let mut reference_sops = 0u64;
    for &workers in &[1usize, 2, 4, 8] {
        let svc = service_for(workers, None);
        let report = svc.serve(&traffic, 64).expect("serve run");
        assert_eq!(
            report.finished_sessions, sessions as u64,
            "every session must finish"
        );
        assert_eq!(report.windows_shed, 0, "nominal load must not shed");
        if workers == 1 {
            reference_sops = report.metrics.sops;
        }
        assert_eq!(
            report.metrics.sops, reference_sops,
            "session results must be worker-count invariant"
        );
        println!(
            "{workers} worker(s): {:7.2} sessions/s  {:8.2} windows/s  {}  shed {:.2} %",
            report.sessions_per_sec(),
            report.windows_per_sec(),
            report.latency.line(),
            100.0 * report.shed_rate(),
        );
        emit_json(
            "serve_streaming",
            &[
                ("workers", workers as f64),
                ("sessions", sessions as f64),
                ("sessions_per_sec", report.sessions_per_sec()),
                ("windows_per_sec", report.windows_per_sec()),
                ("p50_ms", report.latency.p50() * 1e3),
                ("p95_ms", report.latency.p95() * 1e3),
                ("p99_ms", report.latency.p99() * 1e3),
                ("shed_rate", report.shed_rate()),
                ("evictions", report.evictions as f64),
            ],
        );
    }
    println!("\nacceptance: shed rate 0 under nominal load at every pool size");

    // Early-exit trade-off: frames saved vs rolling-accuracy delta against
    // the no-exit baseline, at increasing confidence bounds.
    section("early exit — frames saved vs rolling accuracy (4 workers)");
    let baseline = service_for(4, None).serve(&traffic, 64).expect("baseline run");
    let base_acc = baseline.rolling_correct as f64 / baseline.sessions.max(1) as f64;
    let base_frames = baseline.metrics.timesteps;
    for &margin in &[0.5f64, 1.0, 2.0] {
        let svc = service_for(4, Some(margin));
        let report = svc.serve(&traffic, 64).expect("early-exit run");
        assert_eq!(report.finished_sessions, sessions as u64);
        let acc = report.rolling_correct as f64 / report.sessions.max(1) as f64;
        let saved_frac = report.frames_saved as f64 / base_frames.max(1) as f64;
        println!(
            "margin {margin:4.1}:  {:4} exits  {:5} frames saved ({:5.1} %)  accuracy {:5.1} % (delta {:+5.1} pp)",
            report.early_exits,
            report.frames_saved,
            100.0 * saved_frac,
            100.0 * acc,
            100.0 * (acc - base_acc),
        );
        emit_json(
            "serve_early_exit",
            &[
                ("margin", margin),
                ("early_exits", report.early_exits as f64),
                ("frames_saved", report.frames_saved as f64),
                ("frames_saved_frac", saved_frac),
                ("rolling_accuracy", acc),
                ("accuracy_delta", acc - base_acc),
            ],
        );
    }

    // The same exporters `flexspim serve --dump-telemetry` prints,
    // exercised on the bench workload so the serve-path instrumentation
    // stays wired end to end.
    section("telemetry exporters — metrics registry + flight recorder (2 workers)");
    let svc = DeploymentSpec::builder("serve-bench-telemetry")
        .network(&bench_net())
        .macros(MACROS)
        .policy(Policy::HsOpt)
        .native_backend(SEED)
        .workers(2)
        .telemetry_enabled(true)
        .build()
        .expect("telemetry spec is valid")
        .deploy()
        .expect("telemetry spec deploys")
        .service()
        .expect("service materializes");
    let report = svc.serve(&traffic, 64).expect("telemetry run");
    assert_eq!(report.finished_sessions, sessions as u64);
    let snap = svc.metrics().snapshot();
    let admitted = snap.counter_total("flexspim_serve_admitted_total");
    let done = snap.counter_total("flexspim_serve_windows_done_total");
    let shed = snap.counter_total("flexspim_serve_shed_total");
    assert!(admitted > 0, "instrumented run must admit windows");
    assert_eq!(shed, 0, "nominal load must not shed");
    assert_eq!(done, admitted, "every admitted window must commit");
    assert!(
        svc.metrics().prometheus_text().contains("flexspim_serve_windows_done_total"),
        "Prometheus export must carry the serve families"
    );
    println!(
        "registry: {admitted} admitted, {done} done, {shed} shed  |  {}",
        svc.recorder().dump().lines().next().unwrap_or_default()
    );
    emit_json(
        "serve_telemetry",
        &[
            ("admitted", admitted as f64),
            ("windows_done", done as f64),
            ("shed", shed as f64),
            ("queue_wait_samples", snap.histogram_count("flexspim_serve_queue_wait_seconds") as f64),
            ("flight_recorded", svc.recorder().recorded() as f64),
        ],
    );
}
