//! Engine throughput: samples/sec of the batched parallel engine at 1, 2,
//! 4, and 8 workers on a 16-sample synthetic gesture batch.
//!
//! The acceptance bar for the engine PR: >1.5× samples/sec at 4 workers vs
//! 1 worker. The per-worker backend is the pure-Rust `NativeScnn`
//! interpreter (deterministic from one seed), so this runs on any machine
//! with no artifacts; results are additionally cross-checked for
//! worker-count invariance while measuring.
//!
//! ```sh
//! cargo bench --bench engine_throughput          # full run
//! BENCH_QUICK=1 cargo bench --bench engine_throughput   # CI smoke
//! ```
//!
//! Besides the human-readable table, one `BENCH_JSON {...}` line per
//! worker count is emitted (samples/sec keyed by worker count) so the
//! bench trajectory can be scraped into `BENCH_*.json` across PRs.

use flexspim::coordinator::Engine;
use flexspim::dataflow::Policy;
use flexspim::deploy::DeploymentSpec;
use flexspim::events::{EventStream, GestureClass, GestureGenerator};
use flexspim::snn::network::scnn_dvs_gesture;
use flexspim::snn::{LayerSpec, Network, Resolution};
use flexspim::util::bench::{emit_json, fmt_time, quick_mode, section};
use flexspim::util::rng::Rng;

const SEED: u64 = 42;
const MACROS: usize = 16;

/// Materialize the engine from a deployment spec — the same entry point
/// `flexspim run --config` uses, so the bench measures the deployed
/// configuration, not a bespoke wiring.
fn engine_for(net: &Network, workers: usize) -> Engine {
    DeploymentSpec::builder(&net.name)
        .network(net)
        .macros(MACROS)
        .policy(Policy::HsOpt)
        .native_backend(SEED)
        .workers(workers)
        .build()
        .expect("bench spec is valid")
        .deploy()
        .expect("bench spec deploys")
        .engine()
        .expect("engine materializes")
}

fn gesture_batch(n: usize) -> Vec<(EventStream, usize)> {
    let gen = GestureGenerator::default_48();
    let mut rng = Rng::new(7);
    (0..n)
        .map(|i| {
            let label = i % 10;
            (gen.sample(GestureClass::from_label(label), &mut rng), label)
        })
        .collect()
}

/// A mid-size SCNN: heavy enough that per-sample work dominates thread
/// orchestration, light enough for quick bench turnaround.
fn bench_net() -> Network {
    let r = Resolution::new(4, 9);
    Network::new(
        "engine-bench",
        vec![
            LayerSpec::conv("C1", 2, 8, 3, 2, 1, 48, 48, r),
            LayerSpec::conv("C2", 8, 16, 3, 2, 1, 24, 24, Resolution::new(5, 10)),
            LayerSpec::conv("C3", 16, 16, 3, 1, 1, 12, 12, Resolution::new(5, 10)),
            LayerSpec::fc("F1", 16 * 12 * 12, 64, r),
            LayerSpec::fc("F2", 64, 10, Resolution::new(5, 10)),
        ],
        8,
    )
}

fn throughput(net: &Network, data: &[(EventStream, usize)], workers: usize, reps: usize) -> f64 {
    let engine = engine_for(net, workers);
    // Warm-up run (thread pool spin-up, allocator, branch predictors).
    let warm = engine.run_batch(data).expect("warm-up batch");
    let mut best = 0.0f64;
    let reference_sops = warm.metrics.sops;
    for _ in 0..reps {
        let r = engine.run_batch(data).expect("bench batch");
        assert_eq!(
            r.metrics.sops, reference_sops,
            "throughput runs must stay bit-identical"
        );
        best = best.max(r.samples_per_sec());
    }
    best
}

fn main() {
    let quick = quick_mode();
    let batch = if quick { 8 } else { 16 };
    let reps = if quick { 1 } else { 3 };
    section(&format!("engine throughput — {batch}-sample synthetic gesture batch"));
    let net = bench_net();
    let data = gesture_batch(batch);

    let mut base = 0.0;
    for &workers in &[1usize, 2, 4, 8] {
        let sps = throughput(&net, &data, workers, reps);
        if workers == 1 {
            base = sps;
        }
        let speedup = if base > 0.0 { sps / base } else { 0.0 };
        println!(
            "{workers} worker(s): {sps:8.2} samples/s  ({:>10}/sample)  speedup {speedup:4.2}x",
            fmt_time(1.0 / sps.max(1e-12)),
        );
        emit_json(
            "engine_throughput",
            &[
                ("workers", workers as f64),
                ("batch", batch as f64),
                ("samples_per_sec", sps),
                ("speedup", speedup),
            ],
        );
    }
    println!("\nacceptance: 4-worker speedup must exceed 1.50x over 1 worker");

    if quick {
        return;
    }
    section("reference workload — full SCNN (paper Fig. 4a) on 4 workers");
    let full = scnn_dvs_gesture();
    let small = gesture_batch(4);
    for &workers in &[1usize, 4] {
        let engine = engine_for(&full, workers);
        let r = engine.run_batch(&small).expect("full-net batch");
        println!(
            "{workers} worker(s): {:8.3} samples/s over {} samples ({} SOPs modeled)",
            r.samples_per_sec(),
            r.results.len(),
            r.metrics.sops,
        );
        emit_json(
            "engine_throughput_full_scnn",
            &[("workers", workers as f64), ("samples_per_sec", r.samples_per_sec())],
        );
    }
}
