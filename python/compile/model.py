"""Layer-2 JAX model: the paper's six-conv + three-FC spiking CNN.

Mirrors `rust/src/snn/network.rs::scnn_dvs_gesture` exactly: input
2×48×48 event frames, 10 output classes, per-layer FlexSpIM resolutions.

Two execution paths:

* **Integer inference path** (`scnn_step`): the AOT artifact the Rust
  coordinator runs per timestep. Quantization parameters (modulus, half,
  threshold per layer) are *runtime arguments*, mirroring the chip's
  runtime-reconfigurable operand resolution — one compiled executable
  serves every resolution in the Fig. 6 sweep. The synaptic accumulation
  (the op the CIM array performs) runs in the Pallas kernels; the
  wrap/fire/reset periphery (the PC circuits) is plain XLA.

* **Float surrogate path** (`scnn_step_float`): differentiable version
  for the surrogate-gradient trainer (train.py).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .kernels import ref
from .kernels.cim_kernel import NEURON_TILE, POS_BLOCK

# ---------------------------------------------------------------------------
# Architecture description (must match rust/src/snn/network.rs).

# (name, kind, params, (w_bits, p_bits))
#   conv: (in_ch, out_ch, k, stride, pad, in_h, in_w)
#   fc:   (in_dim, out_dim)
LAYERS = [
    ("L1", "conv", (2, 12, 3, 1, 1, 48, 48), (4, 9)),
    ("L2", "conv", (12, 24, 3, 2, 1, 48, 48), (5, 10)),
    ("L3", "conv", (24, 24, 3, 1, 1, 24, 24), (5, 10)),
    ("L4", "conv", (24, 48, 3, 2, 1, 24, 24), (6, 11)),
    ("L5", "conv", (48, 48, 3, 1, 1, 12, 12), (6, 11)),
    ("L6", "conv", (48, 96, 3, 2, 1, 12, 12), (7, 12)),
    ("FC1", "fc", (96 * 6 * 6, 256), (5, 10)),
    ("FC2", "fc", (256, 128), (5, 10)),
    ("FC3", "fc", (128, 10), (7, 12)),
]

TIMESTEPS = 16
NUM_CLASSES = 10
INPUT_SHAPE = (2, 48, 48)


def conv_out_hw(params):
    """(oh, ow) of a conv layer spec."""
    _, _, k, stride, pad, h, w = params
    return ((h + 2 * pad - k) // stride + 1, (w + 2 * pad - k) // stride + 1)


def weight_shape(kind, params):
    """Weight tensor shape for a layer."""
    if kind == "conv":
        ic, oc, k, *_ = params
        return (oc, ic, k, k)
    i, o = params
    return (o, i)


def vmem_shape(kind, params):
    """Membrane tensor shape for a layer."""
    if kind == "conv":
        oc = params[1]
        oh, ow = conv_out_hw(params)
        return (oc, oh, ow)
    return (params[1],)


INIT_GAIN = 3.0  # keeps spike rates alive through all 9 layers at init
                 # (He gain √2 starves layers ≥ L4 of spikes — measured
                 # rates drop to 0 and gradients die; see test_train.py)


def init_params(seed: int = 0):
    """Spiking-aware float32 initialization: `N(0, (g/√fan_in)²)` with a
    gain tuned so every layer fires at a healthy rate on DVS-sparse input."""
    key = jax.random.PRNGKey(seed)
    params = []
    for (_, kind, p, _) in LAYERS:
        key, sub = jax.random.split(key)
        shape = weight_shape(kind, p)
        fan_in = int(np.prod(shape[1:]))
        params.append(jax.random.normal(sub, shape, jnp.float32)
                      * (INIT_GAIN / np.sqrt(fan_in)))
    return params


def _round_half_away(x):
    """Round half away from zero — matches Rust's `f32::round`, unlike
    numpy's banker's rounding; keeps the two quantizers bit-identical."""
    return jnp.where(x >= 0, jnp.floor(x + 0.5), jnp.ceil(x - 0.5))


def quantize_params(params, resolutions=None):
    """Post-training quantization of float weights.

    Per layer: scale s = max|W| / (2^(w_bits-1) - 1) in float32;
    W_q = round_half_away(W / s); theta_q = round(1.0 / s) clamped to the
    p_bits range (the float model's threshold is 1.0). All arithmetic is
    float32 so the Rust quantizer (rust/src/runtime/weights.rs) produces
    bit-identical integers. Returns (int_weights, qparams int32[n, 3])
    where qparams rows are (modulus, half, theta) for the runtime-dynamic
    wrap — resolution is a *runtime* parameter, like on the chip.
    """
    if resolutions is None:
        resolutions = [r for (_, _, _, r) in LAYERS]
    int_ws, qrows = [], []
    for w, (w_bits, p_bits) in zip(params, resolutions):
        max_q = (1 << (w_bits - 1)) - 1
        maxabs = jnp.max(jnp.abs(w)).astype(jnp.float32)
        scale = jnp.maximum(maxabs / np.float32(max(max_q, 1)),
                            np.float32(1e-12))
        wq = jnp.clip(_round_half_away(w / scale), -max_q - 1, max_q)
        int_ws.append(wq.astype(jnp.int32))
        theta = int(np.clip(np.float32(np.round(1.0 / float(scale))),
                            1, (1 << (p_bits - 1)) - 1))
        qrows.append((1 << p_bits, 1 << (p_bits - 1), theta))
    return int_ws, jnp.asarray(qrows, jnp.int32)


def init_vmems():
    """Zeroed membrane state for all layers."""
    return [jnp.zeros(vmem_shape(kind, p), jnp.int32) for (_, kind, p, _) in LAYERS]


# ---------------------------------------------------------------------------
# Pallas accumulate kernels (dynamic-resolution variants: the kernel does
# the CIM-array accumulate; wrap/fire run in XLA with runtime qparams).


def _acc_fc_kernel(w_ref, s_ref, out_ref):
    out_ref[...] = jnp.dot(w_ref[...], s_ref[...],
                           preferred_element_type=jnp.int32)


def pallas_matvec(weights, spikes):
    """int32[out, in] @ int32[in] via the tiled Pallas kernel."""
    out_dim, in_dim = weights.shape
    pad = (-out_dim) % NEURON_TILE
    if pad:
        weights = jnp.pad(weights, ((0, pad), (0, 0)))
    padded = out_dim + pad
    acc = pl.pallas_call(
        _acc_fc_kernel,
        grid=(padded // NEURON_TILE,),
        in_specs=[
            pl.BlockSpec((NEURON_TILE, in_dim), lambda i: (i, 0)),
            pl.BlockSpec((in_dim,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((NEURON_TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded,), jnp.int32),
        interpret=True,
    )(weights, spikes)
    return acc[:out_dim]


def _acc_mm_kernel(w_ref, p_ref, out_ref):
    out_ref[...] = jnp.dot(w_ref[...], p_ref[...],
                           preferred_element_type=jnp.int32)


def pallas_matmul(wmat, patches_t):
    """int32[out, fan] @ int32[fan, P] via the tiled Pallas kernel."""
    out_ch, fan = wmat.shape
    _, n_pos = patches_t.shape
    cpad = (-out_ch) % NEURON_TILE
    ppad = (-n_pos) % POS_BLOCK
    if cpad:
        wmat = jnp.pad(wmat, ((0, cpad), (0, 0)))
    if ppad:
        patches_t = jnp.pad(patches_t, ((0, 0), (0, ppad)))
    pc, pp = out_ch + cpad, n_pos + ppad
    acc = pl.pallas_call(
        _acc_mm_kernel,
        grid=(pc // NEURON_TILE, pp // POS_BLOCK),
        in_specs=[
            pl.BlockSpec((NEURON_TILE, fan), lambda i, j: (i, 0)),
            pl.BlockSpec((fan, POS_BLOCK), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((NEURON_TILE, POS_BLOCK), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pc, pp), jnp.int32),
        interpret=True,
    )(wmat, patches_t)
    return acc[:out_ch, :n_pos]


def _dyn_wrap(v, m, half):
    """Runtime-modulus two's-complement wrap (m, half are traced i32)."""
    return jnp.mod(v + half, m) - half


def _dyn_fire(v, m, half, theta):
    spk = (v >= theta).astype(jnp.int32)
    return spk, _dyn_wrap(v - spk * theta, m, half)


# ---------------------------------------------------------------------------
# Integer inference step (the AOT artifact body).


def scnn_step(spikes_in, qparams, *args):
    """One SNN timestep over the whole network.

    Args:
      spikes_in: int32[2, 48, 48] binary input frame.
      qparams:   int32[9, 3] rows of (modulus, half, theta) per layer.
      *args:     9 int32 weight tensors followed by 9 int32 vmem tensors.

    Returns:
      (out_spikes int32[10], new vmems ×9, spike_counts int32[9])
    """
    n = len(LAYERS)
    weights, vmems = list(args[:n]), list(args[n:])
    x = spikes_in
    new_vmems, counts = [], []
    for li, (_, kind, p, _) in enumerate(LAYERS):
        m, half, theta = qparams[li, 0], qparams[li, 1], qparams[li, 2]
        if kind == "conv":
            ic, oc, k, stride, pad, h, w = p
            patches, (oh, ow) = ref.im2col(x, k, stride, pad)
            wmat = weights[li].reshape(oc, ic * k * k)
            acc = pallas_matmul(wmat, patches.T).reshape(oc, oh, ow)
        else:
            x = x.reshape(-1)
            acc = pallas_matvec(weights[li], x)
        v = _dyn_wrap(vmems[li] + acc, m, half)
        spk, v = _dyn_fire(v, m, half, theta)
        new_vmems.append(v)
        counts.append(jnp.sum(spk))
        x = spk
    return (x, *new_vmems, jnp.stack(counts))


def scnn_step_reference(spikes_in, qparams, weights, vmems):
    """Pure-jnp oracle for `scnn_step` (no Pallas), for pytest."""
    x = spikes_in
    new_vmems, counts = [], []
    for li, (_, kind, p, _) in enumerate(LAYERS):
        m, half, theta = (int(qparams[li, 0]), int(qparams[li, 1]),
                          int(qparams[li, 2]))
        p_bits = int(np.log2(m))
        if kind == "conv":
            _, _, k, stride, pad, _, _ = p
            spk, v = ref.if_step_conv(weights[li], x, vmems[li], theta,
                                      p_bits, stride, pad)
        else:
            spk, v = ref.if_step_fc(weights[li], x.reshape(-1), vmems[li],
                                    theta, p_bits)
        new_vmems.append(v)
        counts.append(int(jnp.sum(spk)))
        x = spk
    return x, new_vmems, counts


# ---------------------------------------------------------------------------
# Float surrogate path (training).

SURROGATE_SLOPE = 4.0
FLOAT_THETA = 1.0
FLOAT_LEAK = 1.0  # pure IF (no leak), as in the paper's Fig. 1b


@jax.custom_vjp
def spike_surrogate(v):
    """Heaviside spike with a fast-sigmoid surrogate gradient."""
    return (v >= FLOAT_THETA).astype(jnp.float32)


def _spike_fwd(v):
    return spike_surrogate(v), v


def _spike_bwd(v, g):
    # Fast sigmoid derivative centered at theta.
    x = SURROGATE_SLOPE * (v - FLOAT_THETA)
    grad = SURROGATE_SLOPE / (1.0 + jnp.abs(x)) ** 2
    return (g * grad,)


spike_surrogate.defvjp(_spike_fwd, _spike_bwd)


def scnn_step_float(params, spikes_in, vmems):
    """Differentiable float IF step (same topology, float semantics)."""
    import jax.lax as lax

    x = spikes_in.astype(jnp.float32)
    new_vmems = []
    for li, (_, kind, p, _) in enumerate(LAYERS):
        if kind == "conv":
            _, _, k, stride, pad, _, _ = p
            acc = lax.conv_general_dilated(
                x[None], params[li],
                window_strides=(stride, stride),
                padding=[(pad, pad), (pad, pad)],
                dimension_numbers=("NCHW", "OIHW", "NCHW"),
            )[0]
        else:
            acc = params[li] @ x.reshape(-1)
        v = FLOAT_LEAK * vmems[li] + acc
        spk = spike_surrogate(v)
        v = v - spk * FLOAT_THETA
        new_vmems.append(v)
        x = spk
    return x, new_vmems


def init_vmems_float():
    """Zeroed float membrane state."""
    return [jnp.zeros(vmem_shape(kind, p), jnp.float32)
            for (_, kind, p, _) in LAYERS]
