"""AOT compiler: lower the L2 model to HLO-text artifacts for the Rust
runtime.

HLO *text* is the interchange format (NOT `.serialize()`): jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Artifacts (all under artifacts/):
  scnn_step.hlo.txt       full-network single-timestep integer inference
                          (runtime-dynamic quantization parameters)
  layer_<name>.hlo.txt    per-layer fixed-resolution IF steps (Pallas
                          full-IF kernels) for the per-layer pipeline
  train_step.hlo.txt      surrogate-gradient SGD step (B=4, T=16 BPTT)
  weights.bin             float32 weights (random-init; retrain with
                          `python -m compile.train` or the Rust e2e driver)
  golden/*.txt            golden vectors for Rust cross-validation

Python runs only here, at build time; the Rust binary is self-contained
afterwards.
"""

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, train
from .kernels import cim_kernel, ref


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(shape, dtype)


def export_scnn_step(outdir: str) -> str:
    """Lower the full-network timestep with dynamic qparams."""
    n = len(model.LAYERS)
    args = [spec(model.INPUT_SHAPE), spec((n, 3))]
    args += [spec(model.weight_shape(k, p)) for (_, k, p, _) in model.LAYERS]
    args += [spec(model.vmem_shape(k, p)) for (_, k, p, _) in model.LAYERS]
    lowered = jax.jit(model.scnn_step).lower(*args)
    text = to_hlo_text(lowered)
    path = os.path.join(outdir, "scnn_step.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    return path


def export_layer_steps(outdir: str) -> list:
    """Per-layer fixed-resolution IF steps using the full-IF Pallas
    kernels (static theta/p_bits baked per layer)."""
    paths = []
    for (name, kind, p, (w_bits, p_bits)) in model.LAYERS:
        theta = max(((1 << (p_bits - 1)) - 1) // 2, 1)
        if kind == "conv":
            ic, oc, k, stride, pad, h, w = p

            def step(wt, s, v, *, theta=theta, p_bits=p_bits,
                     stride=stride, pad=pad):
                return cim_kernel.if_step_conv(wt, s, v, theta, p_bits,
                                               stride, pad)

            args = [spec((oc, ic, k, k)), spec((ic, h, w)),
                    spec(model.vmem_shape(kind, p))]
        else:
            i, o = p

            def step(wt, s, v, *, theta=theta, p_bits=p_bits):
                return cim_kernel.if_step_fc(wt, s, v, theta, p_bits)

            args = [spec((o, i)), spec((i,)), spec((o,))]
        lowered = jax.jit(step).lower(*args)
        path = os.path.join(outdir, f"layer_{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(to_hlo_text(lowered))
        paths.append(path)
    return paths


def export_train_step(outdir: str, batch: int = 4) -> str:
    """Lower one SGD training step (no donation in the AOT artifact —
    the Rust driver keeps explicit buffers)."""

    def step(params, momentum, frames, labels, lr):
        (loss, acc), grads = jax.value_and_grad(
            train.loss_fn, has_aux=True)(params, frames, labels)
        beta = 0.9
        new_m = [beta * m + g for m, g in zip(momentum, grads)]
        new_p = [p - lr * m for p, m in zip(params, new_m)]
        return (*new_p, *new_m, loss, acc)

    pspecs = [spec(model.weight_shape(k, p), jnp.float32)
              for (_, k, p, _) in model.LAYERS]
    args = [pspecs, pspecs,
            spec((batch, model.TIMESTEPS, *model.INPUT_SHAPE), jnp.float32),
            spec((batch,), jnp.int32), spec((), jnp.float32)]
    lowered = jax.jit(step).lower(*args)
    path = os.path.join(outdir, "train_step.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    return path


def export_weights(outdir: str, seed: int = 0) -> str:
    """Random-init float weights (deterministic); the trained set comes
    from `compile.train` or the Rust training driver."""
    path = os.path.join(outdir, "weights.bin")
    params = model.init_params(seed)
    train.save_weights(params, path)
    return path


def _write_ints(f, arr):
    f.write(" ".join(str(int(x)) for x in np.asarray(arr).reshape(-1)))
    f.write("\n")


def export_golden(outdir: str, seed: int = 7) -> list:
    """Golden vectors: (a) FC IF step cases for the Rust LIF/CIM
    simulators, (b) a full-network 3-timestep trace for the runtime
    integration test, (c) the quantization cross-check."""
    gdir = os.path.join(outdir, "golden")
    os.makedirs(gdir, exist_ok=True)
    rng = np.random.default_rng(seed)
    paths = []

    # (a) FC IF step cases across resolutions.
    path = os.path.join(gdir, "if_step_fc.txt")
    with open(path, "w") as f:
        cases = [(4, 9, 5, 8), (5, 10, 3, 17), (8, 16, 16, 16),
                 (2, 6, 4, 4), (7, 12, 10, 33)]
        f.write(f"{len(cases)}\n")
        for (w_bits, p_bits, out_dim, in_dim) in cases:
            lo, hi = ref.min_val(w_bits), ref.max_val(w_bits)
            w = rng.integers(lo, hi + 1, (out_dim, in_dim))
            s = rng.integers(0, 2, in_dim)
            v = rng.integers(ref.min_val(p_bits), ref.max_val(p_bits) + 1,
                             out_dim)
            theta = max(ref.max_val(p_bits) // 2, 1)
            spk, v2 = ref.if_step_fc(jnp.asarray(w, jnp.int32),
                                     jnp.asarray(s, jnp.int32),
                                     jnp.asarray(v, jnp.int32),
                                     theta, p_bits)
            f.write(f"{w_bits} {p_bits} {theta} {out_dim} {in_dim}\n")
            for arr in (w, s, v, spk, v2):
                _write_ints(f, arr)
    paths.append(path)

    # (b) Full-network trace: quantized weights from the shipped
    # weights.bin, 3 timesteps, expected per-layer spike counts.
    params = model.init_params(0)  # must match export_weights(seed=0)
    int_ws, qparams = model.quantize_params(params)
    frame = rng.integers(0, 2, model.INPUT_SHAPE) * (
        rng.random(model.INPUT_SHAPE) < 0.08)
    frame = jnp.asarray(frame, jnp.int32)
    vmems = model.init_vmems()
    path = os.path.join(gdir, "scnn_trace.txt")
    with open(path, "w") as f:
        f.write("3\n")
        _write_ints(f, qparams)
        _write_ints(f, frame)
        for _ in range(3):
            out = model.scnn_step(frame, qparams, *int_ws, *vmems)
            spk_out, vmems, counts = out[0], list(out[1:-1]), out[-1]
            _write_ints(f, spk_out)
            _write_ints(f, counts)
    paths.append(path)

    # (c) Quantization cross-check: per-layer scale-derived theta and a
    # weight checksum, to pin Rust's quantizer to Python's.
    path = os.path.join(gdir, "quantize_check.txt")
    with open(path, "w") as f:
        f.write(f"{len(int_ws)}\n")
        for wq, (m, half, theta) in zip(int_ws, np.asarray(qparams)):
            a = np.asarray(wq, np.int64)
            f.write(f"{m} {half} {theta} {a.sum()} "
                    f"{np.abs(a).sum()} {a.min()} {a.max()}\n")
    paths.append(path)
    return paths


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--skip-train-step", action="store_true",
                    help="skip the (large) train_step artifact")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    print("lowering scnn_step ...")
    print("  ", export_scnn_step(args.out))
    print("lowering per-layer steps ...")
    for p in export_layer_steps(args.out):
        print("  ", p)
    if not args.skip_train_step:
        print("lowering train_step ...")
        print("  ", export_train_step(args.out))
    print("writing weights ...")
    print("  ", export_weights(args.out))
    print("writing golden vectors ...")
    for p in export_golden(args.out):
        print("  ", p)
    print("done")


if __name__ == "__main__":
    main()
