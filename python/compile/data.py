"""Synthetic DVS-gesture data for build-time training and tests.

NumPy port of the Rust generator (`rust/src/events/synthetic.rs`): ten
parametric blob motions + Poisson noise, binned into per-timestep binary
2-channel frames. The two implementations share the class definitions but
are *not* bit-identical (independent RNGs); both produce the same
classification task at the same sparsity band — the property the
experiments need. See DESIGN.md §Substitutions.
"""

import numpy as np

WIDTH = HEIGHT = 48
TIMESTEPS = 16
NUM_CLASSES = 10
MOTION_STEPS = 64
BLOB_RADIUS = 0.10
EDGE_EVENT_PROB = 0.55
NOISE_RATE = 2.0  # events / pixel / s
DURATION_S = 0.1


def _centers(cls: int, t: float):
    """Blob center(s) at normalized time t, per class (mirrors Rust)."""
    tau = 2 * np.pi
    osc = np.sin(tau * 3.0 * t)
    if cls == 0:   # hand clap
        return [(0.5 - 0.25 * abs(osc), 0.5), (0.5 + 0.25 * abs(osc), 0.5)]
    if cls == 1:   # right wave
        return [(0.7 + 0.18 * osc, 0.35)]
    if cls == 2:   # left wave
        return [(0.3 + 0.18 * osc, 0.35)]
    if cls in (3, 4, 5, 6):  # circles: right/left × cw/ccw
        cx = 0.65 if cls in (3, 4) else 0.35
        sign = -1.0 if cls in (3, 5) else 1.0
        a = tau * 2.0 * t
        return [(cx + 0.18 * np.cos(a), 0.5 + sign * 0.18 * np.sin(a))]
    if cls == 7:   # arm roll
        a = tau * t
        return [(0.5 + 0.3 * np.cos(a), 0.5 + 0.3 * np.sin(a))]
    if cls == 8:   # air drums
        return [(0.35, 0.5 + 0.2 * osc), (0.65, 0.5 - 0.2 * osc)]
    return [(0.5 + 0.15 * osc, 0.6 + 0.15 * osc)]  # air guitar


def sample_frames(cls: int, rng: np.random.Generator,
                  timesteps: int = TIMESTEPS) -> np.ndarray:
    """One sample: float32[T, 2, H, W] binary frames."""
    frames = np.zeros((timesteps, 2, HEIGHT, WIDTH), np.float32)
    steps_per_frame = MOTION_STEPS // timesteps
    prev = _centers(cls, 0.0)
    yy, xx = np.mgrid[0:HEIGHT, 0:WIDTH]
    nx_grid = (xx + 0.5) / WIDTH
    ny_grid = (yy + 0.5) / HEIGHT
    for step in range(1, MOTION_STEPS):
        t = step / MOTION_STEPS
        frame = min(step // steps_per_frame, timesteps - 1)
        centers = _centers(cls, t)
        for ci, (cx, cy) in enumerate(centers):
            px, py = prev[min(ci, len(prev) - 1)]
            dx, dy = cx - px, cy - py
            speed = np.hypot(dx, dy)
            if speed < 1e-9:
                continue
            nx = nx_grid - cx
            ny = ny_grid - cy
            d = np.hypot(nx, ny)
            rim = (d <= BLOB_RADIUS) & (d >= BLOB_RADIUS * 0.55)
            with np.errstate(divide="ignore", invalid="ignore"):
                along = np.where(rim, (nx * dx + ny * dy) / (d * speed), 0.0)
            p_fire = EDGE_EVENT_PROB * np.abs(along) * rim
            fired = rng.random(p_fire.shape) < p_fire
            on = fired & (along > 0)
            off = fired & (along <= 0)
            frames[frame, 0][on] = 1.0
            frames[frame, 1][off] = 1.0
        prev = centers
    # Background noise.
    lam = NOISE_RATE * WIDTH * HEIGHT * DURATION_S
    n_noise = rng.poisson(lam)
    for _ in range(int(n_noise)):
        frames[rng.integers(timesteps), rng.integers(2),
               rng.integers(HEIGHT), rng.integers(WIDTH)] = 1.0
    return frames


def batch(batch_size: int, rng: np.random.Generator,
          timesteps: int = TIMESTEPS):
    """(frames float32[B, T, 2, H, W], labels int32[B]) with balanced-ish
    random classes."""
    labels = rng.integers(0, NUM_CLASSES, batch_size).astype(np.int32)
    frames = np.stack([sample_frames(int(c), rng, timesteps) for c in labels])
    return frames, labels


def dataset(per_class: int, rng: np.random.Generator,
            timesteps: int = TIMESTEPS):
    """Balanced labeled dataset: (frames [N,T,2,H,W], labels [N])."""
    frames, labels = [], []
    for cls in range(NUM_CLASSES):
        for _ in range(per_class):
            frames.append(sample_frames(cls, rng, timesteps))
            labels.append(cls)
    return np.stack(frames), np.asarray(labels, np.int32)


def sparsity(frames: np.ndarray) -> float:
    """1 − active fraction over all (t, c, y, x) slots."""
    return 1.0 - float(frames.mean())
