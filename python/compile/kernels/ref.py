"""Pure-jnp correctness oracle for the FlexSpIM compute path.

Defines the *exact* integer semantics of the quantized integrate-and-fire
(IF) update that the CIM macro executes bit-serially in silicon (and that
the Rust simulator `rust/src/cim/macro_unit.rs` reproduces bit-for-bit):

    v    <- wrap(v + W_q @ s, p_bits)        two's-complement wrap
    spk  <- v >= theta
    v    <- spk ? v - theta : v              reset by subtraction

All tensors are int32; `wrap` emulates arbitrary-width two's-complement
arithmetic so any `p_bits` in [1, 31] is exact. The Pallas kernels in
`cim_kernel.py` must match this oracle on every shape/bit-width (pytest +
hypothesis), and golden vectors exported from here must match the Rust
fixed-point LIF (rust/tests/golden_vectors.rs).
"""

import jax.numpy as jnp
import numpy as np


def wrap(v, bits: int):
    """Two's-complement wrap of int32 values into `bits` width."""
    assert 1 <= bits <= 31, f"bits={bits} unsupported"
    m = np.int32(1 << bits)
    half = np.int32(1 << (bits - 1))
    r = jnp.mod(v + half, m)
    return r - half


def min_val(bits: int) -> int:
    """Smallest signed value at `bits` width."""
    return -(1 << (bits - 1))


def max_val(bits: int) -> int:
    """Largest signed value at `bits` width."""
    return (1 << (bits - 1)) - 1


def if_step_fc(weights, spikes, vmem, theta: int, p_bits: int):
    """One IF timestep of a fully-connected layer.

    Args:
      weights: int32[out, in] quantized synaptic weights (w_bits-ranged).
      spikes:  int32[in] binary input spikes (0/1).
      vmem:    int32[out] membrane potentials (p_bits-ranged).
      theta:   firing threshold (int).
      p_bits:  membrane-potential width.

    Returns:
      (spikes_out int32[out] 0/1, vmem' int32[out])
    """
    acc = weights @ spikes
    v = wrap(vmem + acc, p_bits)
    spk = (v >= theta).astype(jnp.int32)
    v = wrap(v - spk * theta, p_bits)
    return spk, v


def if_step_conv(weights, spikes, vmem, theta: int, p_bits: int,
                 stride: int = 1, pad: int = 1):
    """One IF timestep of a 2-D convolutional layer.

    Args:
      weights: int32[out_ch, in_ch, k, k].
      spikes:  int32[in_ch, h, w] binary input spikes.
      vmem:    int32[out_ch, oh, ow].
      theta, p_bits: as in `if_step_fc`.

    Integer convolution is evaluated exactly via float32 lax.conv: all
    accumulations stay far below 2^24 (fan-in ≤ 864 × |w| ≤ 2^12 for every
    supported configuration), so the float path is bit-exact.

    Returns:
      (spikes_out int32[out_ch, oh, ow], vmem')
    """
    import jax.lax as lax

    lhs = spikes[None].astype(jnp.float32)        # [1, in_ch, h, w]
    rhs = weights.astype(jnp.float32)             # [out_ch, in_ch, k, k]
    acc = lax.conv_general_dilated(
        lhs, rhs,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )[0].astype(jnp.int32)                        # [out_ch, oh, ow]
    v = wrap(vmem + acc, p_bits)
    spk = (v >= theta).astype(jnp.int32)
    v = wrap(v - spk * theta, p_bits)
    return spk, v


def im2col(spikes, k: int, stride: int, pad: int):
    """Unfold int32[in_ch, h, w] into int32[oh*ow, in_ch*k*k] patches.

    This is the layout the CIM controller streams to the macro: each
    output position becomes one fan-in vector, so every conv layer reduces
    to the same matvec-style IF update the macro executes. Fan-in order is
    (dy, dx) fastest within channel-major blocks, matching
    `weights.reshape(out_ch, in_ch * k * k)`.
    """
    c, h, w = spikes.shape
    x = jnp.pad(spikes, ((0, 0), (pad, pad), (pad, pad)))
    oh = (h + 2 * pad - k) // stride + 1
    ow = (w + 2 * pad - k) // stride + 1
    cols = []
    for dy in range(k):
        for dx in range(k):
            patch = x[:, dy:dy + stride * oh:stride, dx:dx + stride * ow:stride]
            cols.append(patch.reshape(c, -1))     # [c, oh*ow]
    stacked = jnp.stack(cols, axis=1)             # [c, k*k, oh*ow]
    return stacked.reshape(c * k * k, -1).T, (oh, ow)


def if_step_conv_im2col(weights, spikes, vmem, theta: int, p_bits: int,
                        stride: int = 1, pad: int = 1):
    """Conv IF step via im2col + matmul — bit-identical to `if_step_conv`,
    and the reference for the Pallas conv path (same decomposition)."""
    out_ch, in_ch, k, _ = weights.shape
    patches, (oh, ow) = im2col(spikes, k, stride, pad)   # [P, c*k*k]
    wmat = weights.reshape(out_ch, in_ch * k * k)        # [out_ch, c*k*k]
    acc = patches @ wmat.T                               # [P, out_ch]
    acc = acc.T.reshape(out_ch, oh, ow)
    v = wrap(vmem + acc, p_bits)
    spk = (v >= theta).astype(jnp.int32)
    v = wrap(v - spk * theta, p_bits)
    return spk, v
