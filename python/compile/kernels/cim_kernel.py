"""Layer-1 Pallas kernels: the quantized integrate-and-fire hot loop.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's compute
hot-spot is the bit-serial XNOR/AND-accumulate of the CIM macro. On a
TPU-shaped target the same insight — *operand layout is a free variable* —
maps to: arbitrary (w_bits, p_bits) quantization folded into the kernel as
wrap/threshold constants (resolution flexibility), BlockSpec tiling over
output neurons ↔ the paper's column-parallel neuron mapping (operand
shaping), and carrying the membrane state through the kernel so it stays
resident (output stationarity).

Kernels are lowered with `interpret=True`: the CPU PJRT plugin cannot run
Mosaic custom-calls, and interpret-mode lowers to plain HLO the Rust
runtime executes. Correctness target: bit-identical to `ref.py` for every
shape and bit-width (python/tests/test_kernel.py, hypothesis).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# Output-neuron tile: matches an MXU-friendly 128-lane block; on the real
# chip this corresponds to the group of neurons mapped column-parallel in
# one macro pass.
NEURON_TILE = 128


def _wrap(v, p_bits: int):
    """Two's-complement wrap inside the kernel (int32 lanes)."""
    m = np.int32(1 << p_bits)
    half = np.int32(1 << (p_bits - 1))
    return jnp.mod(v + half, m) - half


def _if_tile_kernel(w_ref, s_ref, v_ref, spk_ref, v_out_ref, *,
                    theta: int, p_bits: int):
    """One output-neuron tile: accumulate + wrap + fire + reset.

    w_ref: int32[TILE, IN]    weight tile (weight-stationary block)
    s_ref: int32[IN]          input spike vector (broadcast)
    v_ref: int32[TILE]        membrane potentials in
    spk_ref / v_out_ref: outputs
    """
    acc = jnp.dot(w_ref[...], s_ref[...], preferred_element_type=jnp.int32)
    v = _wrap(v_ref[...] + acc, p_bits)
    spk = (v >= theta).astype(jnp.int32)
    v_out_ref[...] = _wrap(v - spk * theta, p_bits)
    spk_ref[...] = spk


def if_step_fc(weights, spikes, vmem, theta: int, p_bits: int):
    """Pallas FC IF step, tiled over output neurons.

    Same contract as `ref.if_step_fc`; output dimension is padded to the
    neuron tile internally (padding neurons carry zero weights and theta
    can never fire them within one step if theta > 0).
    """
    out_dim, in_dim = weights.shape
    assert spikes.shape == (in_dim,) and vmem.shape == (out_dim,)
    assert theta > 0

    pad = (-out_dim) % NEURON_TILE
    if pad:
        weights = jnp.pad(weights, ((0, pad), (0, 0)))
        vmem = jnp.pad(vmem, (0, pad))
    padded = out_dim + pad
    grid = padded // NEURON_TILE

    kernel = functools.partial(_if_tile_kernel, theta=theta, p_bits=p_bits)
    spk, v = pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((NEURON_TILE, in_dim), lambda i: (i, 0)),
            pl.BlockSpec((in_dim,), lambda i: (0,)),
            pl.BlockSpec((NEURON_TILE,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((NEURON_TILE,), lambda i: (i,)),
            pl.BlockSpec((NEURON_TILE,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((padded,), jnp.int32),
            jax.ShapeDtypeStruct((padded,), jnp.int32),
        ],
        interpret=True,
    )(weights, spikes, vmem)
    return spk[:out_dim], v[:out_dim]


def _if_conv_tile_kernel(w_ref, p_ref, v_ref, spk_ref, v_out_ref, *,
                         theta: int, p_bits: int):
    """One (output-channel-tile × position-block) conv IF tile.

    w_ref: int32[CTILE, FAN]   weight matrix tile
    p_ref: int32[FAN, PBLOCK]  im2col patch block
    v_ref: int32[CTILE, PBLOCK]
    """
    acc = jnp.dot(w_ref[...], p_ref[...], preferred_element_type=jnp.int32)
    v = _wrap(v_ref[...] + acc, p_bits)
    spk = (v >= theta).astype(jnp.int32)
    v_out_ref[...] = _wrap(v - spk * theta, p_bits)
    spk_ref[...] = spk


# Position-block: the second tiling axis (output pixels per macro pass).
POS_BLOCK = 144


def if_step_conv(weights, spikes, vmem, theta: int, p_bits: int,
                 stride: int = 1, pad: int = 1):
    """Pallas conv IF step via im2col + the tiled matmul kernel.

    Same contract as `ref.if_step_conv`. The im2col unfold happens in jnp
    (it lowers to cheap gathers/reshapes fused by XLA); the arithmetic
    hot loop — the part the CIM macro implements — is the Pallas kernel.
    """
    from . import ref as _ref

    out_ch, in_ch, k, _ = weights.shape
    patches, (oh, ow) = _ref.im2col(spikes, k, stride, pad)  # [P, FAN]
    n_pos = oh * ow
    fan = in_ch * k * k
    wmat = weights.reshape(out_ch, fan)
    vflat = vmem.reshape(out_ch, n_pos)

    # Pad both tile axes.
    cpad = (-out_ch) % NEURON_TILE
    ppad = (-n_pos) % POS_BLOCK
    if cpad:
        wmat = jnp.pad(wmat, ((0, cpad), (0, 0)))
        vflat = jnp.pad(vflat, ((0, cpad), (0, 0)))
    if ppad:
        patches = jnp.pad(patches, ((0, ppad), (0, 0)))
        vflat = jnp.pad(vflat, ((0, 0), (0, ppad)))
    pc = out_ch + cpad
    pp = n_pos + ppad

    kernel = functools.partial(_if_conv_tile_kernel, theta=theta, p_bits=p_bits)
    spk, v = pl.pallas_call(
        kernel,
        grid=(pc // NEURON_TILE, pp // POS_BLOCK),
        in_specs=[
            pl.BlockSpec((NEURON_TILE, fan), lambda i, j: (i, 0)),
            pl.BlockSpec((fan, POS_BLOCK), lambda i, j: (0, j)),
            pl.BlockSpec((NEURON_TILE, POS_BLOCK), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((NEURON_TILE, POS_BLOCK), lambda i, j: (i, j)),
            pl.BlockSpec((NEURON_TILE, POS_BLOCK), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((pc, pp), jnp.int32),
            jax.ShapeDtypeStruct((pc, pp), jnp.int32),
        ],
        interpret=True,
    )(wmat, patches.T, vflat)
    spk = spk[:out_ch, :n_pos].reshape(out_ch, oh, ow)
    v = v[:out_ch, :n_pos].reshape(out_ch, oh, ow)
    return spk, v


def vmem_footprint_bytes(out_tile: int, in_dim: int, pos_block: int = 1) -> int:
    """Estimated VMEM bytes for one kernel invocation's blocks (weights +
    patches + state + outputs, int32). Used by the DESIGN.md §Perf roofline
    estimate — interpret mode gives no real VMEM numbers."""
    w = out_tile * in_dim
    p = in_dim * pos_block
    state = 3 * out_tile * pos_block
    return 4 * (w + p + state)
