"""Surrogate-gradient trainer for the SCNN (build-time Python).

Provides (a) the jittable `train_step` that aot.py lowers to HLO so the
*Rust* coordinator can drive training end-to-end (examples/train_snn.rs),
and (b) a convenience CLI (`python -m compile.train`) that trains float
weights briefly and writes `artifacts/weights.bin` for the inference
examples.

Readout: logits = Σ_t spikes(FC3) + 0.1 · v_final(FC3) (rate coding with a
membrane tiebreaker), cross-entropy loss, plain SGD with momentum.
"""

import argparse
import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import data, model


def forward_logits(params, frames):
    """Run T timesteps for one sample; frames float32[T, 2, H, W]."""
    vmems = model.init_vmems_float()
    rate = jnp.zeros(model.NUM_CLASSES, jnp.float32)
    out_v = None
    for t in range(frames.shape[0]):
        spk, vmems = model.scnn_step_float(params, frames[t], vmems)
        rate = rate + spk
        out_v = vmems[-1]
    return rate + 0.1 * out_v


def loss_fn(params, frames_batch, labels):
    """Mean cross-entropy over the batch; frames [B, T, 2, H, W]."""
    logits = jax.vmap(lambda f: forward_logits(params, f))(frames_batch)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32),
                               axis=1).mean()
    acc = (jnp.argmax(logits, axis=1) == labels).mean()
    return nll, acc


@functools.partial(jax.jit, donate_argnums=(0, 1))
def train_step(params, momentum, frames_batch, labels, lr):
    """One SGD-with-momentum step. Returns (params', momentum', loss, acc).

    This function is AOT-lowered to `artifacts/train_step.hlo.txt`; the
    Rust driver supplies batches and the learning rate at runtime.
    """
    (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, frames_batch, labels)
    beta = 0.9
    new_m = [beta * m + g for m, g in zip(momentum, grads)]
    new_p = [p - lr * m for p, m in zip(params, new_m)]
    return new_p, new_m, loss, acc


def evaluate_float(params, frames, labels) -> float:
    """Float-model accuracy on a labeled set."""
    correct = 0
    for f, l in zip(frames, labels):
        logits = forward_logits(params, jnp.asarray(f))
        correct += int(jnp.argmax(logits)) == int(l)
    return correct / len(labels)


def evaluate_int(params, frames, labels, resolutions=None) -> float:
    """Quantized integer-model accuracy (the silicon-faithful path)."""
    int_ws, qparams = model.quantize_params(params, resolutions)
    correct = 0
    for f, l in zip(frames, labels):
        vmems = model.init_vmems()
        rate = np.zeros(model.NUM_CLASSES, np.int64)
        for t in range(f.shape[0]):
            spk_in = jnp.asarray(f[t], jnp.int32)
            out = model.scnn_step(spk_in, qparams, *int_ws, *vmems)
            spk_out, vmems = out[0], list(out[1:-1])
            rate += np.asarray(spk_out)
        correct += int(np.argmax(rate)) == int(l)
    return correct / len(labels)


def train(steps: int = 60, batch_size: int = 4, lr: float = 0.05,
          seed: int = 0, log_every: int = 10, progress=print):
    """Train from scratch; returns (params, loss_history)."""
    params = model.init_params(seed)
    momentum = [jnp.zeros_like(p) for p in params]
    rng = np.random.default_rng(seed)
    history = []
    for step in range(steps):
        frames, labels = data.batch(batch_size, rng)
        params, momentum, loss, acc = train_step(
            params, momentum, jnp.asarray(frames), jnp.asarray(labels),
            jnp.float32(lr))
        history.append(float(loss))
        if step % log_every == 0 or step == steps - 1:
            progress(f"step {step:4d}  loss {float(loss):.4f}  "
                     f"batch-acc {float(acc):.2f}")
    return params, history


def save_weights(params, path: str):
    """Serialize float32 weights: magic, n_layers, per-layer dims + data.

    Little-endian custom format parsed by rust/src/runtime/weights.rs.
    """
    with open(path, "wb") as f:
        f.write(b"FSPW")
        f.write(np.int32(len(params)).tobytes())
        for (name, kind, p, (w_bits, p_bits)), w in zip(model.LAYERS, params):
            wn = np.asarray(w, np.float32)
            nb = name.encode()
            f.write(np.int32(len(nb)).tobytes())
            f.write(nb)
            f.write(np.int32(w_bits).tobytes())
            f.write(np.int32(p_bits).tobytes())
            f.write(np.int32(wn.ndim).tobytes())
            for d in wn.shape:
                f.write(np.int32(d).tobytes())
            f.write(wn.tobytes())


def load_weights(path: str):
    """Inverse of `save_weights` (for tests)."""
    import struct

    with open(path, "rb") as f:
        assert f.read(4) == b"FSPW"
        (n,) = struct.unpack("<i", f.read(4))
        params = []
        for _ in range(n):
            (ln,) = struct.unpack("<i", f.read(4))
            f.read(ln)  # name
            struct.unpack("<ii", f.read(8))  # w_bits, p_bits
            (nd,) = struct.unpack("<i", f.read(4))
            shape = struct.unpack(f"<{nd}i", f.read(4 * nd))
            count = int(np.prod(shape))
            w = np.frombuffer(f.read(4 * count), np.float32).reshape(shape)
            params.append(jnp.asarray(w))
        return params


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="../artifacts/weights.bin")
    ap.add_argument("--eval", type=int, default=0,
                    help="samples/class for post-training int evaluation")
    args = ap.parse_args()

    params, _ = train(args.steps, args.batch, args.lr, args.seed)
    save_weights(params, args.out)
    print(f"wrote {args.out}")
    if args.eval:
        rng = np.random.default_rng(123)
        frames, labels = data.dataset(args.eval, rng)
        acc = evaluate_int(params, frames, labels)
        print(f"int accuracy on {len(labels)} samples: {acc:.3f}")


if __name__ == "__main__":
    main()
