"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

The CORE correctness signal of the compile path: for arbitrary shapes and
bit-widths (FlexSpIM's resolution flexibility axis), the tiled Pallas
kernels must be bit-identical to ref.py.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import cim_kernel as ck
from compile.kernels import ref


def _mk_fc(rng, out_dim, in_dim, w_bits, p_bits):
    w = rng.integers(ref.min_val(w_bits), ref.max_val(w_bits) + 1,
                     (out_dim, in_dim))
    s = rng.integers(0, 2, in_dim)
    v = rng.integers(ref.min_val(p_bits), ref.max_val(p_bits) + 1, out_dim)
    return (jnp.asarray(w, jnp.int32), jnp.asarray(s, jnp.int32),
            jnp.asarray(v, jnp.int32))


class TestWrap:
    def test_wrap_examples(self):
        assert int(ref.wrap(jnp.int32(128), 8)) == -128
        assert int(ref.wrap(jnp.int32(-129), 8)) == 127
        assert int(ref.wrap(jnp.int32(5), 4)) == 5
        assert int(ref.wrap(jnp.int32(8), 4)) == -8

    @given(st.integers(min_value=1, max_value=20),
           st.integers(min_value=-(1 << 24), max_value=1 << 24))
    @settings(max_examples=200, deadline=None)
    def test_wrap_matches_python_semantics(self, bits, v):
        m = 1 << bits
        r = ((v + m // 2) % m) - m // 2
        assert int(ref.wrap(jnp.int32(v), bits)) == r

    def test_range_helpers(self):
        assert ref.min_val(8) == -128 and ref.max_val(8) == 127
        assert ref.min_val(1) == -1 and ref.max_val(1) == 0


class TestFcKernel:
    @given(
        out_dim=st.integers(1, 200),
        in_dim=st.integers(1, 96),
        w_bits=st.integers(1, 8),
        p_bits=st.integers(2, 16),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=40, deadline=None)
    def test_matches_ref(self, out_dim, in_dim, w_bits, p_bits, seed):
        rng = np.random.default_rng(seed)
        w, s, v = _mk_fc(rng, out_dim, in_dim, w_bits, p_bits)
        theta = max(ref.max_val(p_bits) // 2, 1)
        r_spk, r_v = ref.if_step_fc(w, s, v, theta, p_bits)
        k_spk, k_v = ck.if_step_fc(w, s, v, theta, p_bits)
        np.testing.assert_array_equal(np.asarray(r_spk), np.asarray(k_spk))
        np.testing.assert_array_equal(np.asarray(r_v), np.asarray(k_v))

    def test_tile_boundary_sizes(self):
        # Exactly at / around the 128-neuron tile boundary.
        rng = np.random.default_rng(1)
        for out_dim in (127, 128, 129, 256):
            w, s, v = _mk_fc(rng, out_dim, 33, 4, 10)
            r = ref.if_step_fc(w, s, v, 7, 10)
            k = ck.if_step_fc(w, s, v, 7, 10)
            np.testing.assert_array_equal(np.asarray(r[0]), np.asarray(k[0]))
            np.testing.assert_array_equal(np.asarray(r[1]), np.asarray(k[1]))

    def test_state_evolution_over_timesteps(self):
        rng = np.random.default_rng(2)
        w, s, v = _mk_fc(rng, 10, 20, 4, 9)
        rv, kv = v, v
        for t in range(5):
            s = jnp.asarray(rng.integers(0, 2, 20), jnp.int32)
            r_spk, rv = ref.if_step_fc(w, s, rv, 11, 9)
            k_spk, kv = ck.if_step_fc(w, s, kv, 11, 9)
            np.testing.assert_array_equal(np.asarray(rv), np.asarray(kv),
                                          err_msg=f"t={t}")

    def test_wraparound_exercised(self):
        # Saturating inputs to force wrap at p_bits = 4.
        w = jnp.full((4, 8), 7, jnp.int32)
        s = jnp.ones(8, jnp.int32)
        v = jnp.full(4, 5, jnp.int32)
        r = ref.if_step_fc(w, s, v, 6, 4)
        k = ck.if_step_fc(w, s, v, 6, 4)
        np.testing.assert_array_equal(np.asarray(r[1]), np.asarray(k[1]))


class TestConvKernel:
    @given(
        in_ch=st.integers(1, 6),
        out_ch=st.integers(1, 8),
        h=st.integers(4, 14),
        stride=st.sampled_from([1, 2]),
        w_bits=st.integers(2, 7),
        p_bits=st.integers(4, 14),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=25, deadline=None)
    def test_matches_ref(self, in_ch, out_ch, h, stride, w_bits, p_bits, seed):
        rng = np.random.default_rng(seed)
        k = 3
        w = jnp.asarray(rng.integers(ref.min_val(w_bits),
                                     ref.max_val(w_bits) + 1,
                                     (out_ch, in_ch, k, k)), jnp.int32)
        s = jnp.asarray(rng.integers(0, 2, (in_ch, h, h)), jnp.int32)
        oh = (h + 2 - k) // stride + 1
        v = jnp.asarray(rng.integers(ref.min_val(p_bits),
                                     ref.max_val(p_bits) + 1,
                                     (out_ch, oh, oh)), jnp.int32)
        theta = max(ref.max_val(p_bits) // 2, 1)
        r = ref.if_step_conv(w, s, v, theta, p_bits, stride, 1)
        kk = ck.if_step_conv(w, s, v, theta, p_bits, stride, 1)
        np.testing.assert_array_equal(np.asarray(r[0]), np.asarray(kk[0]))
        np.testing.assert_array_equal(np.asarray(r[1]), np.asarray(kk[1]))

    def test_im2col_reference_agrees_with_lax_conv(self):
        rng = np.random.default_rng(3)
        w = jnp.asarray(rng.integers(-4, 5, (5, 3, 3, 3)), jnp.int32)
        s = jnp.asarray(rng.integers(0, 2, (3, 9, 9)), jnp.int32)
        v = jnp.zeros((5, 5, 5), jnp.int32)
        a = ref.if_step_conv(w, s, v, 9, 10, 2, 1)
        b = ref.if_step_conv_im2col(w, s, v, 9, 10, 2, 1)
        np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
        np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


class TestVmemFootprint:
    def test_footprint_grows_with_tiles(self):
        small = ck.vmem_footprint_bytes(128, 64)
        big = ck.vmem_footprint_bytes(128, 1024)
        assert big > small
        # The default FC tile at the SCNN's largest fan-in fits in a
        # 16 MB-class VMEM budget with ample headroom.
        assert ck.vmem_footprint_bytes(128, 3456, 1) < 4 * 2**20
