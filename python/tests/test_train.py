"""Trainer tests: loss decreases, weights serialize, int eval runs."""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import data, model, train


class TestTrainStep:
    def test_loss_decreases_over_short_run(self):
        # A few steps on a fixed tiny batch must reduce loss (overfit).
        params = model.init_params(0)
        momentum = [jnp.zeros_like(p) for p in params]
        rng = np.random.default_rng(0)
        frames, labels = data.batch(2, rng, timesteps=4)
        frames, labels = jnp.asarray(frames), jnp.asarray(labels)
        first = None
        loss = None
        for _ in range(8):
            params, momentum, loss, _ = train.train_step(
                params, momentum, frames, labels, jnp.float32(0.1))
            if first is None:
                first = float(loss)
        assert float(loss) < first, f"{float(loss)} !< {first}"

    def test_gradients_change_all_layers(self):
        import jax

        params = model.init_params(1)
        rng = np.random.default_rng(1)
        frames, labels = data.batch(2, rng, timesteps=4)
        (_, _), grads = jax.value_and_grad(train.loss_fn, has_aux=True)(
            params, jnp.asarray(frames), jnp.asarray(labels))
        for g, (name, *_rest) in zip(grads, model.LAYERS):
            assert float(jnp.abs(g).sum()) > 0, f"dead gradient in {name}"


class TestWeightsIo:
    def test_roundtrip(self, tmp_path):
        params = model.init_params(2)
        path = os.path.join(tmp_path, "w.bin")
        train.save_weights(params, path)
        loaded = train.load_weights(path)
        assert len(loaded) == len(params)
        for a, b in zip(params, loaded):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_format_header(self, tmp_path):
        params = model.init_params(2)
        path = os.path.join(tmp_path, "w.bin")
        train.save_weights(params, path)
        with open(path, "rb") as f:
            assert f.read(4) == b"FSPW"


class TestIntEvaluation:
    def test_eval_runs_and_bounded(self):
        params = model.init_params(3)
        rng = np.random.default_rng(3)
        frames, labels = data.dataset(1, rng, timesteps=4)
        acc = train.evaluate_int(params, frames[:5], labels[:5])
        assert 0.0 <= acc <= 1.0

    def test_eval_respects_resolutions(self):
        params = model.init_params(3)
        rng = np.random.default_rng(3)
        frames, labels = data.dataset(1, rng, timesteps=2)
        res = [(2, 6)] * len(model.LAYERS)
        acc = train.evaluate_int(params, frames[:3], labels[:3], res)
        assert 0.0 <= acc <= 1.0
