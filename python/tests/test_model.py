"""L2 model tests: topology, quantization, integer step vs reference."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import data, model
from compile.kernels import ref


class TestTopology:
    def test_layer_chain_shapes(self):
        # Output of each layer must feed the next.
        prev = int(np.prod(model.INPUT_SHAPE))
        for (name, kind, p, _) in model.LAYERS:
            if kind == "conv":
                ic, oc, k, stride, pad, h, w = p
                assert ic * h * w == prev, name
                oh, ow = model.conv_out_hw(p)
                prev = oc * oh * ow
            else:
                i, o = p
                assert i == prev, name
                prev = o
        assert prev == model.NUM_CLASSES

    def test_matches_rust_network(self):
        # Mirror of rust/src/snn/network.rs::scnn_dvs_gesture.
        assert len(model.LAYERS) == 9
        assert model.LAYERS[0][2][:2] == (2, 12)
        assert model.LAYERS[5][2][:2] == (48, 96)
        assert model.LAYERS[6][2] == (96 * 6 * 6, 256)
        assert [r for (_, _, _, r) in model.LAYERS] == [
            (4, 9), (5, 10), (5, 10), (6, 11), (6, 11), (7, 12),
            (5, 10), (5, 10), (7, 12)]

    def test_param_count(self):
        params = model.init_params(0)
        total = sum(int(np.prod(p.shape)) for p in params)
        # ~1.1 M parameters for the 48×48 SCNN.
        assert 900_000 < total < 1_300_000


class TestQuantization:
    def test_weights_in_range(self):
        params = model.init_params(1)
        int_ws, qparams = model.quantize_params(params)
        for wq, (_, _, _, (w_bits, p_bits)), row in zip(
                int_ws, model.LAYERS, np.asarray(qparams)):
            lo, hi = ref.min_val(w_bits), ref.max_val(w_bits)
            a = np.asarray(wq)
            assert a.min() >= lo and a.max() <= hi
            m, half, theta = row
            assert m == 1 << p_bits and half == 1 << (p_bits - 1)
            assert 1 <= theta <= ref.max_val(p_bits)

    def test_half_away_rounding(self):
        x = jnp.asarray([0.5, 1.5, -0.5, -1.5, 2.4, -2.4], jnp.float32)
        r = np.asarray(model._round_half_away(x))
        np.testing.assert_array_equal(r, [1.0, 2.0, -1.0, -2.0, 2.0, -2.0])

    def test_custom_resolutions(self):
        params = model.init_params(2)
        res = [(2, 6)] * len(model.LAYERS)
        int_ws, qparams = model.quantize_params(params, res)
        for wq in int_ws:
            a = np.asarray(wq)
            assert a.min() >= -2 and a.max() <= 1
        assert all(np.asarray(qparams)[:, 0] == 64)


class TestIntegerStep:
    @pytest.fixture(scope="class")
    def setup(self):
        params = model.init_params(3)
        int_ws, qparams = model.quantize_params(params)
        rng = np.random.default_rng(5)
        frame = jnp.asarray(
            (rng.random(model.INPUT_SHAPE) < 0.08).astype(np.int32))
        return int_ws, qparams, frame

    def test_pallas_step_matches_reference(self, setup):
        int_ws, qparams, frame = setup
        vmems = model.init_vmems()
        out = model.scnn_step(frame, qparams, *int_ws, *vmems)
        spk, new_vmems, counts = out[0], list(out[1:-1]), out[-1]
        r_spk, r_vmems, r_counts = model.scnn_step_reference(
            frame, np.asarray(qparams), int_ws, vmems)
        np.testing.assert_array_equal(np.asarray(spk), np.asarray(r_spk))
        for a, b in zip(new_vmems, r_vmems):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(counts), r_counts)

    def test_multi_timestep_state(self, setup):
        int_ws, qparams, frame = setup
        vmems = model.init_vmems()
        for t in range(3):
            out = model.scnn_step(frame, qparams, *int_ws, *vmems)
            vmems = list(out[1:-1])
        # Membrane state evolves and stays within p_bits ranges.
        for v, (_, _, _, (_, p_bits)) in zip(vmems, model.LAYERS):
            a = np.asarray(v)
            assert a.min() >= ref.min_val(p_bits)
            assert a.max() <= ref.max_val(p_bits)
        assert any(np.asarray(v).any() for v in vmems)

    def test_resolution_is_runtime_dynamic(self, setup):
        # The same compiled step must work at a different resolution by
        # changing only qparams + weights — the chip's key flexibility.
        params = model.init_params(3)
        res = [(3, 8)] * len(model.LAYERS)
        int_ws, qparams = model.quantize_params(params, res)
        vmems = model.init_vmems()
        frame = setup[2]
        out = model.scnn_step(frame, qparams, *int_ws, *vmems)
        for v in out[1:-1]:
            a = np.asarray(v)
            assert a.min() >= ref.min_val(8) and a.max() <= ref.max_val(8)


class TestFloatModel:
    def test_step_shapes_and_gradients(self):
        params = model.init_params(4)
        vmems = model.init_vmems_float()
        x = jnp.zeros(model.INPUT_SHAPE, jnp.float32).at[0, 20:28, 20:28].set(1.0)
        spk, vmems = model.scnn_step_float(params, x, vmems)
        assert spk.shape == (model.NUM_CLASSES,)

        import jax

        def scalar_loss(p):
            s, vs = model.scnn_step_float(p, x, model.init_vmems_float())
            return jnp.sum(vs[-1])

        grads = jax.grad(scalar_loss)(params)
        norms = [float(jnp.abs(g).sum()) for g in grads]
        assert any(n > 0 for n in norms), "surrogate gradient must flow"

    def test_surrogate_spike_values(self):
        v = jnp.asarray([0.0, 0.99, 1.0, 5.0], jnp.float32)
        s = np.asarray(model.spike_surrogate(v))
        np.testing.assert_array_equal(s, [0.0, 0.0, 1.0, 1.0])
