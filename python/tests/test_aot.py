"""AOT export tests: HLO text artifacts parse and are well-formed."""

import os

import numpy as np
import pytest

from compile import aot, model

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def entry_param_count(text: str) -> int:
    """Parameters of the ENTRY computation only (fusions/loops have their
    own parameter lists)."""
    count, in_entry = 0, False
    for line in text.split("\n"):
        if line.startswith("ENTRY"):
            in_entry = True
        elif in_entry and line.startswith("}"):
            break
        elif in_entry and "parameter(" in line:
            count += 1
    return count


class TestHloText:
    def test_scnn_step_lowering(self, tmp_path):
        p = aot.export_scnn_step(str(tmp_path))
        text = open(p).read()
        assert text.startswith("HloModule")
        assert "ENTRY" in text
        # 20 parameters: spikes, qparams, 9 weights, 9 vmems.
        assert entry_param_count(text) == 20

    def test_layer_step_lowering(self, tmp_path):
        paths = aot.export_layer_steps(str(tmp_path))
        assert len(paths) == 9
        for p in paths:
            text = open(p).read()
            assert text.startswith("HloModule")
            assert entry_param_count(text) == 3  # w, spikes, vmem

    def test_golden_files(self, tmp_path):
        paths = aot.export_golden(str(tmp_path))
        fc = open(paths[0]).read().split("\n")
        n_cases = int(fc[0])
        assert n_cases >= 5
        # Each case: header + 5 data lines.
        assert len([l for l in fc if l.strip()]) == 1 + 6 * n_cases

    def test_quantize_check_content(self, tmp_path):
        paths = aot.export_golden(str(tmp_path))
        lines = open(paths[2]).read().strip().split("\n")
        assert int(lines[0]) == len(model.LAYERS)
        for line in lines[1:]:
            m, half, theta, *_ = (int(x) for x in line.split())
            assert m == 2 * half and theta >= 1


@pytest.mark.skipif(not os.path.exists(os.path.join(ARTIFACTS, "scnn_step.hlo.txt")),
                    reason="artifacts not built")
class TestShippedArtifacts:
    def test_all_artifacts_present(self):
        expected = ["scnn_step.hlo.txt", "train_step.hlo.txt", "weights.bin"]
        expected += [f"layer_{n}.hlo.txt" for (n, *_rest) in model.LAYERS]
        for e in expected:
            assert os.path.exists(os.path.join(ARTIFACTS, e)), e

    def test_weights_bin_loads(self):
        from compile import train as t

        params = t.load_weights(os.path.join(ARTIFACTS, "weights.bin"))
        assert len(params) == len(model.LAYERS)
        for p, (_, kind, spec, _) in zip(params, model.LAYERS):
            assert tuple(p.shape) == model.weight_shape(kind, spec)
