"""Synthetic gesture data tests: shapes, sparsity band, class structure."""

import numpy as np

from compile import data


class TestFrames:
    def test_shapes_and_binary(self):
        rng = np.random.default_rng(0)
        f = data.sample_frames(0, rng)
        assert f.shape == (16, 2, 48, 48)
        assert set(np.unique(f)).issubset({0.0, 1.0})

    def test_sparsity_in_paper_band(self):
        rng = np.random.default_rng(1)
        for cls in range(data.NUM_CLASSES):
            f = data.sample_frames(cls, rng)
            s = data.sparsity(f)
            assert 0.85 <= s <= 0.995, f"class {cls}: sparsity {s:.4f}"

    def test_nonempty_signal(self):
        rng = np.random.default_rng(2)
        for cls in range(data.NUM_CLASSES):
            f = data.sample_frames(cls, rng)
            assert f.sum() > 50, f"class {cls} nearly empty"

    def test_left_right_distinct(self):
        rng = np.random.default_rng(3)
        def mean_x(cls):
            f = data.sample_frames(cls, rng)
            _, _, _, xs = np.nonzero(f)
            return xs.mean()
        assert mean_x(1) > mean_x(2) + 5  # right vs left wave

    def test_batch_and_dataset(self):
        rng = np.random.default_rng(4)
        frames, labels = data.batch(6, rng)
        assert frames.shape == (6, 16, 2, 48, 48)
        assert labels.shape == (6,)
        frames, labels = data.dataset(2, rng)
        assert frames.shape[0] == 20
        assert (np.bincount(labels, minlength=10) == 2).all()

    def test_determinism(self):
        a = data.sample_frames(5, np.random.default_rng(9))
        b = data.sample_frames(5, np.random.default_rng(9))
        np.testing.assert_array_equal(a, b)
